//! The event-driven execution engine (the dispatch core behind every
//! invocation front-end).
//!
//! The paper positions EdgeFaaS "in the critical-path, acting like a
//! router" for every invocation (§3.2.1). This module is that router's
//! execution core: a run queue of in-flight workflow runs whose DAG nodes
//! fire as dependency-completion events, executed by a shared worker pool
//! under per-resource admission limits. Both invocation front-ends sit on
//! top of it:
//!
//! * synchronous [`EdgeFaaS::run_workflow`] = [`EdgeFaaS::submit_workflow`]
//!   + [`EdgeFaaS::wait_workflow`];
//! * asynchronous `invoke_async` = [`EdgeFaaS::spawn_job`] + tracker id
//!   (see [`super::asyncinvoke`]).
//!
//! The engine is generic over the [`crate::simnet::Clock`] the coordinator
//! was built with: under a `RealClock` the worker pool gives true wall-clock
//! parallelism; under a `VirtualClock` the same code path advances virtual
//! time (the benches' mode). Readiness is decided by dependency completion
//! with ready sets sorted by topological index, so chain-shaped DAGs (both
//! paper workflows) fire in the same order under either clock; independent
//! parallel branches may interleave by completion timing.
//!
//! Scheduling decisions interleave across runs: N submitted workflows share
//! the worker pool and the per-resource slots, so a long run does not
//! head-of-line-block a short one. Every node/run completion is also
//! published to [`EdgeFaaS::on_engine_event`] subscribers, which is the hook
//! `reschedule_function` reacts through mid-run.
//!
//! # Hot path & batching
//!
//! The paper puts EdgeFaaS "in the critical-path, acting like a router"
//! for every invocation, so per-invocation overhead bounds system
//! throughput. Two optimizations keep that overhead flat:
//!
//! * **Zero-copy envelopes.** A node's invocation envelope is assembled at
//!   fire time, once per instance, into a shared [`Bytes`] buffer: the
//!   `{"app":...,"function":...` head is serialized exactly once per node
//!   and shared across all placements, and only the per-instance
//!   `inputs`/`resource` tail is appended per placement. Workers and the
//!   batch protocol clone refcounts, never payload bytes, and handler
//!   outputs travel back the same way.
//!
//! * **Per-resource invocation batching.** When a worker acquires a
//!   resource's admission slot it opportunistically drains other queued
//!   instances bound for the *same* resource — admission-deferred ones
//!   always, ready-queue ones only while the resource is saturated
//!   (draining below the admission limit would trade away parallelism an
//!   idle worker could provide) — up to [`DEFAULT_MAX_BATCH`] — and
//!   executes them as one batch: a single
//!   admission-slot acquisition, one backend `Batch` round trip
//!   ([`super::handle::ResourceHandle::invoke_batch`]; per-task fallback for
//!   backends without the verb), and one amortized completion pass that
//!   takes the run-table lock twice per *batch* instead of twice per task.
//!   A batch executes sequentially on one worker, so the per-resource
//!   concurrency bound is unchanged, and results fan back out to their runs
//!   in pop order — the exact order a lone worker would have produced —
//!   preserving the determinism guarantee (identical firing orders/outputs
//!   under `RealClock` and `VirtualClock`, batching on or off). Toggle with
//!   [`EdgeFaaS::set_batching`] / [`EdgeFaaS::set_max_batch`]; measured by
//!   `benches/ablation_concurrency.rs` (`BENCH_hotpath.json`).
//!
//! # QoS: ordering, deadlines, backpressure
//!
//! The paper claims EdgeFaaS "automatically optimizes the scheduling of
//! functions ... according to their performance and privacy requirements".
//! Every submission therefore carries a [`QoS`]: a [`Priority`] class
//! (`Realtime` > `Interactive` > `Batch`; default `Interactive`) and an
//! optional relative deadline in seconds.
//!
//! **Ordering rule.** The ready queue is a priority queue ordered by the
//! triple `(class, absolute deadline, submission sequence)`: strictly by
//! class first, earliest-deadline-first within a class (no deadline sorts
//! last), and FIFO submission order as the deterministic tie-break. Workers
//! and admission-deferred instances follow the same order, so a `Realtime`
//! instance always dispatches before queued `Interactive`/`Batch` work.
//!
//! **Starvation guard (aging).** Strict priority alone would starve `Batch`
//! under sustained higher-class load, so the pop path ages the queue by
//! dispatch count: after [`BATCH_AGE_LIMIT`] consecutive higher-class
//! dispatches while `Batch` work waited, the oldest dispatchable `Batch`
//! task runs next. Counting dispatches (not wall time) keeps the guard
//! identical under `RealClock` and `VirtualClock`.
//!
//! **Class-pure batching.** Per-resource invocation batching only coalesces
//! instances of the *same* class as the slot-holding instance: a `Batch`
//! run can never ride a slot acquired by a `Realtime` pop (and vice versa),
//! so batching cannot reorder work across classes.
//!
//! **Deadlines.** A run's deadline is fixed at submission
//! (`now + deadline_s`). Deadline enforcement happens at dispatch: an
//! instance popped after its run's deadline has passed is *not* executed —
//! the run transitions to [`RunStatus::DeadlineExceeded`], its remaining
//! queued instances drain without occupying backend slots, and
//! [`EngineEvent::DeadlineMissed`] fires so an [`EdgeFaaS::on_engine_event`]
//! policy (e.g. a reschedule hook) can react. Instances already executing
//! are never cancelled — a run whose work completes late still reports
//! `Done`.
//!
//! **Backpressure.** Two configurable bounds
//! ([`EdgeFaaS::set_backpressure`]): total pending (not-yet-finished) runs,
//! and queued instances per resource. A submission that would exceed either
//! bound is refused with [`EngineError::Saturated`] — the REST gateway maps
//! this to `429 Too Many Requests` with a `Retry-After` header — except
//! that a `Realtime`/`Interactive` submission first *sheds* queued
//! `Batch`-class runs (newest first, only runs with no instance currently
//! executing) to make room: under overload the coordinator degrades
//! predictably, Batch first, instead of queueing without bound.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::util::bytes::Bytes;
use crate::util::json::Json;

use super::dag::RunState;
use super::invoker::{parse_outputs, InstanceResult, WorkflowResult};
use super::resource::{Application, EdgeFaaS, ResourceId};

/// Identifier of one submitted workflow run.
pub type RunId = u64;

/// QoS class of a submission (see the module docs' ordering rule).
///
/// Classes are strict: all queued `Realtime` work dispatches before any
/// `Interactive` work, which dispatches before any `Batch` work — except
/// for the aging guard ([`BATCH_AGE_LIMIT`]) that keeps `Batch` from
/// starving under sustained higher-class load.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-critical: jumps every queue.
    Realtime,
    /// The default class for ordinary submissions.
    #[default]
    Interactive,
    /// Throughput-oriented: runs when nothing more urgent waits, is shed
    /// first under backpressure.
    Batch,
}

impl Priority {
    /// Ordering rank (lower dispatches first).
    pub(crate) const fn rank(self) -> u8 {
        match self {
            Priority::Realtime => 0,
            Priority::Interactive => 1,
            Priority::Batch => 2,
        }
    }

    /// Lowercase wire name (`realtime` / `interactive` / `batch`).
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Realtime => "realtime",
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Priority {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Priority> {
        match s {
            "realtime" => Ok(Priority::Realtime),
            "interactive" => Ok(Priority::Interactive),
            "batch" => Ok(Priority::Batch),
            other => Err(anyhow::anyhow!(
                "unknown priority `{other}` (expected realtime|interactive|batch)"
            )),
        }
    }
}

/// Per-submission quality-of-service requirements.
///
/// `deadline_s` is relative to submission time; the engine fixes the
/// absolute deadline at submit. Defaults: `Interactive`, no deadline.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct QoS {
    pub priority: Priority,
    pub deadline_s: Option<f64>,
}

impl QoS {
    /// Shorthand for a class with no deadline.
    pub fn class(priority: Priority) -> QoS {
        QoS { priority, deadline_s: None }
    }

    /// Attach a relative deadline (seconds from submission).
    pub fn with_deadline(mut self, deadline_s: f64) -> QoS {
        self.deadline_s = Some(deadline_s);
        self
    }
}

/// Why a submission was not accepted by the engine.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineError {
    /// Backpressure: the configured queue bounds are reached and nothing
    /// Batch-class could be shed. The REST gateway maps this to
    /// `429 Too Many Requests` with a `Retry-After` header.
    Saturated {
        /// Pending (not yet finished) runs at rejection time.
        pending_runs: usize,
        /// The configured pending-run bound.
        max_pending_runs: usize,
        /// The resource whose queued-instance bound was the binding
        /// constraint, when it was a per-resource rejection.
        saturated_resource: Option<ResourceId>,
        /// Suggested client back-off, seconds.
        retry_after_s: f64,
    },
    /// The submission itself was invalid (unknown application, ...).
    Rejected(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Saturated {
                pending_runs,
                max_pending_runs,
                saturated_resource,
                retry_after_s,
            } => {
                write!(
                    f,
                    "engine saturated: {pending_runs}/{max_pending_runs} pending runs"
                )?;
                if let Some(rid) = saturated_resource {
                    write!(f, " (resource {rid} queue full)")?;
                }
                write!(f, "; retry after {retry_after_s:.0}s")
            }
            EngineError::Rejected(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for EngineError {}

/// Why [`EdgeFaaS::wait_workflow`] returned without a result. Each cause is
/// its own variant so callers can tell "the wait timed out but the run is
/// still in flight" from "the run itself failed" without parsing strings.
#[derive(Debug, Clone, PartialEq)]
pub enum WaitError {
    /// The wait's own timeout elapsed; the run is still executing (not
    /// failed) and can be waited on again.
    Timeout { run: RunId, waited_s: f64 },
    /// The run missed its QoS deadline ([`RunStatus::DeadlineExceeded`]).
    DeadlineExceeded { run: RunId },
    /// The run finished unsuccessfully.
    RunFailed { run: RunId, message: String },
    /// No record of the run: never submitted, or already consumed.
    UnknownRun { run: RunId },
}

impl std::fmt::Display for WaitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WaitError::Timeout { run, waited_s } => write!(
                f,
                "timed out after {waited_s:.3}s waiting for workflow run {run} \
                 (the run is still executing, not failed)"
            ),
            WaitError::DeadlineExceeded { run } => {
                write!(f, "workflow run {run} exceeded its QoS deadline")
            }
            WaitError::RunFailed { run, message } => {
                write!(f, "workflow run {run} failed: {message}")
            }
            WaitError::UnknownRun { run } => write!(f, "unknown workflow run {run}"),
        }
    }
}

impl std::error::Error for WaitError {}

/// Externally visible state of a run.
#[derive(Debug, Clone)]
pub enum RunStatus {
    Running,
    Done(WorkflowResult),
    Failed(String),
    /// The run's QoS deadline passed before its queued work could
    /// dispatch; remaining instances were drained without executing.
    DeadlineExceeded,
}

/// A completion event published to [`EdgeFaaS::on_engine_event`] callbacks.
#[derive(Debug, Clone)]
pub enum EngineEvent {
    /// Every instance of one DAG node finished.
    NodeCompleted {
        run: RunId,
        app: String,
        function: String,
        /// Number of placement instances that executed.
        instances: usize,
        /// Slowest instance latency, seconds.
        latency: f64,
    },
    /// A whole run drained (successfully or not).
    RunCompleted { run: RunId, app: String, ok: bool, duration: f64 },
    /// A run's QoS deadline passed before its queued work could dispatch.
    /// Fires once per run, on the transition; reschedule policies
    /// subscribed via [`EdgeFaaS::on_engine_event`] can resubmit or
    /// migrate in response.
    DeadlineMissed {
        run: RunId,
        app: String,
        /// The configured relative deadline, seconds.
        deadline_s: f64,
        /// How far past the deadline the miss was detected, seconds.
        late_by: f64,
    },
}

/// One schedulable unit: a single placement instance of a DAG node, or an
/// opaque job (the async-invoke front-end).
enum Task {
    Instance(InstanceTask),
    Job {
        class: Priority,
        /// Absolute deadline in integer nanoseconds (`u64::MAX` = none);
        /// for jobs this is an EDF ordering hint only — jobs are opaque and
        /// are never deadline-cancelled.
        deadline_ns: u64,
        job: Box<dyn FnOnce(&Arc<EdgeFaaS>) + Send + 'static>,
    },
}

impl Task {
    fn class(&self) -> Priority {
        match self {
            Task::Instance(t) => t.class,
            Task::Job { class, .. } => *class,
        }
    }

    fn deadline_ns(&self) -> u64 {
        match self {
            Task::Instance(t) => t.deadline_ns,
            Task::Job { deadline_ns, .. } => *deadline_ns,
        }
    }
}

struct InstanceTask {
    run: RunId,
    app: String,
    function: String,
    /// Index into the node's placement list.
    instance: usize,
    resource: ResourceId,
    /// The run's QoS class (queue ordering + class-pure batching).
    class: Priority,
    /// The run's absolute deadline in integer nanoseconds (`u64::MAX` =
    /// no deadline) — the EDF component of the queue key.
    deadline_ns: u64,
    /// Fully-assembled invocation envelope, built once at fire time (the
    /// node-common head is serialized once and shared across placements).
    /// Shared `Bytes`: the batch protocol clones refcounts, not payloads.
    envelope: Bytes,
}

/// Priority-queue key: strict class first, earliest deadline within the
/// class (`u64::MAX` = none, sorts last), then submission sequence for a
/// deterministic FIFO tie-break. Derived `Ord` is lexicographic over the
/// fields in this order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct QKey {
    class: u8,
    deadline_ns: u64,
    seq: u64,
}

impl QKey {
    const MIN: QKey = QKey { class: 0, deadline_ns: 0, seq: 0 };

    /// Smallest key of the `Batch` class (the start of the aged range).
    const BATCH_MIN: QKey =
        QKey { class: Priority::Batch.rank(), deadline_ns: 0, seq: 0 };
}

/// Bookkeeping for one in-flight workflow run.
struct RunEntry {
    app_name: String,
    app: Arc<Application>,
    entry_inputs: HashMap<String, Vec<String>>,
    state: RunState,
    /// Nodes already fired (guards duplicate entrypoints).
    fired: HashSet<String>,
    /// Node -> instances still executing.
    pending: HashMap<String, usize>,
    /// Node -> per-instance results collected so far.
    partial: HashMap<String, Vec<Option<InstanceResult>>>,
    result: WorkflowResult,
    /// Tasks enqueued but not yet finished (0 = run drained).
    open_tasks: usize,
    started: f64,
    /// The QoS the run was submitted with.
    qos: QoS,
    /// Absolute deadline (clock seconds), fixed at submission.
    deadline_abs: Option<f64>,
    /// Set once when the deadline is detected as missed at dispatch.
    deadline_missed: bool,
    failed: Option<String>,
    done: bool,
}

/// Queue + admission state, under a single lock so slot acquisition and
/// release cannot deadlock against the pop path.
struct QueueState {
    /// The QoS-ordered ready queue (see [`QKey`] for the ordering rule).
    ready: BTreeMap<QKey, Task>,
    /// Instances that were popped but found their resource at its admission
    /// limit; re-scanned (in the same QoS order) whenever a slot frees up.
    /// They keep their original key, so age/priority is preserved.
    deferred: BTreeMap<QKey, InstanceTask>,
    /// Resource -> instances currently executing on it.
    in_use: HashMap<ResourceId, usize>,
    /// Monotonic enqueue sequence — the deterministic FIFO tie-break.
    next_seq: u64,
    /// Consecutive higher-class dispatches while Batch work waited (the
    /// aging counter; see [`BATCH_AGE_LIMIT`]).
    since_batch: u64,
    /// Live worker threads.
    workers: usize,
    /// Workers currently executing a task (the rest are polling or about to
    /// exit). `workers - busy` is the free capacity `ensure_workers`
    /// compares against the backlog, so a long-running task never blocks a
    /// short run from getting a fresh worker.
    busy: usize,
}

/// Queued (ready + admission-deferred) instances bound for one resource —
/// the quantity the per-resource backpressure bound limits.
fn queued_on(q: &QueueState, rid: ResourceId) -> usize {
    let ready = q
        .ready
        .values()
        .filter(|t| matches!(t, Task::Instance(ti) if ti.resource == rid))
        .count();
    ready + q.deferred.values().filter(|t| t.resource == rid).count()
}

/// Table of workflow runs plus the retention queue of completed ones.
struct RunTable {
    map: HashMap<RunId, RunEntry>,
    /// Completed runs not yet consumed, oldest first. Bounded by
    /// [`MAX_FINISHED_RUNS`] so submit-and-forget clients (e.g. a crashed
    /// REST poller) cannot grow the coordinator's memory without bound.
    finished: VecDeque<RunId>,
    /// Count of not-yet-finished runs (admission increments, the
    /// completing transition decrements) — the pending-run backpressure
    /// bound compares against this instead of rescanning `map` (which also
    /// holds up to [`MAX_FINISHED_RUNS`] retained finished entries) on
    /// every submission.
    pending_runs: usize,
}

/// Completed-but-unconsumed runs retained before the oldest are evicted.
pub const MAX_FINISHED_RUNS: usize = 1024;

type EventCallback = Arc<dyn Fn(&EdgeFaaS, &EngineEvent) + Send + Sync>;

/// The shared execution core owned by [`EdgeFaaS`].
pub(super) struct EngineCore {
    next_run: AtomicU64,
    max_workers: AtomicUsize,
    per_resource_slots: AtomicUsize,
    /// Largest per-resource invocation batch a worker may drain (1 =
    /// batching off: every instance dispatches individually).
    max_batch: AtomicUsize,
    /// Backpressure: total pending (not yet finished) runs admitted.
    max_pending_runs: AtomicUsize,
    /// Backpressure: queued instances allowed per resource.
    max_queued_per_resource: AtomicUsize,
    queue: Mutex<QueueState>,
    queue_cv: Condvar,
    runs: Mutex<RunTable>,
    done_cv: Condvar,
    callbacks: Mutex<Vec<EventCallback>>,
}

/// Default cap on worker threads (lazily spawned, exit when idle).
pub const DEFAULT_MAX_WORKERS: usize = 16;
/// Default concurrently-executing instances admitted per resource.
pub const DEFAULT_PER_RESOURCE_SLOTS: usize = 8;
/// Default cap on a per-resource invocation batch (see the module docs).
pub const DEFAULT_MAX_BATCH: usize = 16;
/// Default bound on pending (not yet finished) runs before
/// [`EngineError::Saturated`].
pub const DEFAULT_MAX_PENDING_RUNS: usize = 1024;
/// Default bound on queued instances per resource before
/// [`EngineError::Saturated`].
pub const DEFAULT_MAX_QUEUED_PER_RESOURCE: usize = 4096;
/// Aging guard: after this many consecutive higher-class instance
/// dispatches (popped or coalesced into a batching drain) while `Batch`
/// work waited, the oldest dispatchable `Batch` task runs next.
/// Dispatch-count based (not time based) so the guard behaves identically
/// under `RealClock` and `VirtualClock`.
pub const BATCH_AGE_LIMIT: u64 = 16;
/// `Retry-After` hint returned with [`EngineError::Saturated`], seconds.
pub const SATURATED_RETRY_AFTER_S: f64 = 1.0;

impl EngineCore {
    pub(super) fn new() -> EngineCore {
        EngineCore {
            next_run: AtomicU64::new(0),
            max_workers: AtomicUsize::new(DEFAULT_MAX_WORKERS),
            per_resource_slots: AtomicUsize::new(DEFAULT_PER_RESOURCE_SLOTS),
            max_batch: AtomicUsize::new(DEFAULT_MAX_BATCH),
            max_pending_runs: AtomicUsize::new(DEFAULT_MAX_PENDING_RUNS),
            max_queued_per_resource: AtomicUsize::new(DEFAULT_MAX_QUEUED_PER_RESOURCE),
            queue: Mutex::new(QueueState {
                ready: BTreeMap::new(),
                deferred: BTreeMap::new(),
                in_use: HashMap::new(),
                next_seq: 0,
                since_batch: 0,
                workers: 0,
                busy: 0,
            }),
            queue_cv: Condvar::new(),
            runs: Mutex::new(RunTable {
                map: HashMap::new(),
                finished: VecDeque::new(),
                pending_runs: 0,
            }),
            done_cv: Condvar::new(),
            callbacks: Mutex::new(Vec::new()),
        }
    }

    fn enqueue(&self, tasks: Vec<Task>) {
        if tasks.is_empty() {
            return;
        }
        let mut q = self.queue.lock().unwrap();
        for t in tasks {
            let key =
                QKey { class: t.class().rank(), deadline_ns: t.deadline_ns(), seq: q.next_seq };
            q.next_seq += 1;
            q.ready.insert(key, t);
        }
        drop(q);
        self.queue_cv.notify_all();
    }
}

enum Popped {
    Task(Task),
    /// Nothing queued at all: the worker may exit.
    Empty,
    /// Only admission-blocked instances remain: wait for a slot release.
    Blocked,
}

/// Take the best dispatchable task at or above `lo` in key order, merging
/// the ready queue and the admission-deferred set (both are QoS-ordered;
/// the globally smallest dispatchable key wins). Ready instances whose
/// resource is at its admission limit migrate to `deferred` under their
/// original key. Returns `None` when nothing in the range can dispatch.
fn pop_best(q: &mut QueueState, limit: usize, lo: QKey) -> Option<Task> {
    loop {
        let d_key = {
            let in_use = &q.in_use;
            q.deferred
                .range(lo..)
                .find(|(_, t)| in_use.get(&t.resource).copied().unwrap_or(0) < limit)
                .map(|(k, _)| *k)
        };
        let r_key = q.ready.range(lo..).next().map(|(k, _)| *k);
        let take_ready = match (r_key, d_key) {
            (None, None) => return None,
            (Some(rk), Some(dk)) => rk < dk,
            (Some(_), None) => true,
            (None, Some(_)) => false,
        };
        if take_ready {
            let rk = r_key.expect("checked in take_ready");
            let task = q.ready.remove(&rk).expect("key just observed");
            match task {
                Task::Job { .. } => return Some(task),
                Task::Instance(t) => {
                    if q.in_use.get(&t.resource).copied().unwrap_or(0) < limit {
                        *q.in_use.entry(t.resource).or_insert(0) += 1;
                        return Some(Task::Instance(t));
                    }
                    q.deferred.insert(rk, t);
                }
            }
        } else {
            let dk = d_key.expect("checked in take_ready");
            let t = q.deferred.remove(&dk).expect("key just observed");
            *q.in_use.entry(t.resource).or_insert(0) += 1;
            return Some(Task::Instance(t));
        }
    }
}

/// Pop the next task in QoS order, applying the aging guard: once
/// [`BATCH_AGE_LIMIT`] consecutive higher-class tasks have dispatched while
/// `Batch` work waited, the oldest dispatchable `Batch` task goes first.
fn pop_task(q: &mut QueueState, limit: usize) -> Popped {
    let aged = if q.since_batch >= BATCH_AGE_LIMIT {
        pop_best(q, limit, QKey::BATCH_MIN)
    } else {
        None
    };
    let popped = aged.or_else(|| pop_best(q, limit, QKey::MIN));
    match popped {
        Some(task) => {
            if task.class() == Priority::Batch {
                q.since_batch = 0;
            } else {
                let batch_waiting = q.ready.range(QKey::BATCH_MIN..).next().is_some()
                    || q.deferred.range(QKey::BATCH_MIN..).next().is_some();
                q.since_batch = if batch_waiting { q.since_batch + 1 } else { 0 };
            }
            Popped::Task(task)
        }
        None => {
            if q.ready.is_empty() && q.deferred.is_empty() {
                Popped::Empty
            } else {
                Popped::Blocked
            }
        }
    }
}

/// Execute one placement instance: call the resource gateway with the
/// prebuilt envelope and parse the outputs (the invoker's wire format).
///
/// A panicking function handler is caught and converted into an instance
/// error: letting it unwind through the worker would leak the admission
/// slot and busy/worker counts and leave the run's `open_tasks` stuck above
/// zero — wedging a synchronous `run_workflow` caller forever.
fn run_instance(faas: &EdgeFaaS, t: &InstanceTask) -> anyhow::Result<InstanceResult> {
    let invoked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || -> anyhow::Result<InstanceResult> {
            let reg = faas.resource(t.resource)?;
            let qname = EdgeFaaS::qualified(&t.app, &t.function);
            let (out, latency) = reg.handle.invoke(&qname, &t.envelope)?;
            let outputs = parse_outputs(&out)?;
            Ok(InstanceResult { resource: t.resource, outputs, latency })
        },
    ));
    match invoked {
        Ok(result) => result,
        Err(payload) => {
            let what = crate::util::panic_message(&*payload);
            Err(anyhow::anyhow!("function handler panicked: {what}"))
        }
    }
}

/// Pull queued instances bound for `rid` *of the same QoS class as the
/// slot-holding instance* (admission-deferred first, then ready-queue
/// order; both in QoS key order) into `out`, up to `max_total` entries.
/// The drained instances execute sequentially under the admission slot the
/// first instance already holds, so the per-resource concurrency bound is
/// preserved.
///
/// Class purity is a QoS invariant, not an optimization: a `Batch`
/// instance must never ride a slot acquired by a `Realtime` pop — it would
/// effectively jump every queue the ordering rule just made it wait in.
///
/// Ready-queue instances are drained only while the resource is saturated
/// (`in_use >= limit`): below the limit, an idle worker could run them in
/// parallel, and pulling them into this batch would trade that parallelism
/// away. Deferred instances are admission-blocked either way, so joining
/// the batch never costs them anything.
fn drain_same_resource(
    q: &mut QueueState,
    rid: ResourceId,
    class: Priority,
    limit: usize,
    max_total: usize,
    out: &mut Vec<InstanceTask>,
) {
    // No coalescing while a *higher*-class instance waits for this same
    // resource: it is entitled to the slot at the next release, and a
    // drained batch would run up to max_batch lower-class instances ahead
    // of it — a priority inversion the ordering rule forbids. (`..lim` is
    // exactly the keys of strictly higher classes.)
    let lim = QKey { class: class.rank(), deadline_ns: 0, seq: 0 };
    let higher_waits = q
        .ready
        .range(..lim)
        .any(|(_, t)| matches!(t, Task::Instance(ti) if ti.resource == rid))
        || q.deferred.range(..lim).any(|(_, t)| t.resource == rid);
    if higher_waits {
        return;
    }
    let before = out.len();
    let keys: Vec<QKey> = q
        .deferred
        .iter()
        .filter(|(k, t)| k.class == class.rank() && t.resource == rid)
        .map(|(k, _)| *k)
        .take(max_total.saturating_sub(out.len()))
        .collect();
    for k in keys {
        out.push(q.deferred.remove(&k).expect("key just collected"));
    }
    if q.in_use.get(&rid).copied().unwrap_or(0) < limit {
        return;
    }
    let keys: Vec<QKey> = q
        .ready
        .iter()
        .filter(|(k, t)| {
            k.class == class.rank() && matches!(t, Task::Instance(ti) if ti.resource == rid)
        })
        .map(|(k, _)| *k)
        .take(max_total.saturating_sub(out.len()))
        .collect();
    for k in keys {
        match q.ready.remove(&k) {
            Some(Task::Instance(t)) => out.push(t),
            _ => unreachable!("collected an instance key"),
        }
    }
    // Aging accounting: every drained higher-class instance counts toward
    // the starvation bound, exactly like a popped one — otherwise batching
    // would inflate the documented [`BATCH_AGE_LIMIT`] by up to max_batch x
    // (same batch-waiting rule as `pop_task`).
    let drained = (out.len() - before) as u64;
    if drained > 0 && class != Priority::Batch {
        let batch_waiting = q.ready.range(QKey::BATCH_MIN..).next().is_some()
            || q.deferred.range(QKey::BATCH_MIN..).next().is_some();
        if batch_waiting {
            q.since_batch += drained;
        }
    }
}

fn engine_worker(faas: Arc<EdgeFaaS>) {
    loop {
        let task = {
            let mut q = faas.engine.queue.lock().unwrap();
            loop {
                let limit = faas.engine.per_resource_slots.load(Ordering::Relaxed).max(1);
                match pop_task(&mut q, limit) {
                    Popped::Task(t) => {
                        q.busy += 1;
                        break Some(t);
                    }
                    Popped::Empty => {
                        q.workers -= 1;
                        break None;
                    }
                    Popped::Blocked => q = faas.engine.queue_cv.wait(q).unwrap(),
                }
            }
        };
        let Some(task) = task else { return };
        match task {
            Task::Job { job, .. } => {
                // Same containment as run_instance: a panicking job must
                // not kill the worker and leak the busy/worker counts.
                let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(&faas)));
                if ran.is_err() {
                    log::warn!("engine job panicked; worker kept alive");
                }
                let mut q = faas.engine.queue.lock().unwrap();
                q.busy = q.busy.saturating_sub(1);
            }
            Task::Instance(first) => {
                let rid = first.resource;
                let class = first.class;
                // Opportunistically drain more same-resource, same-class
                // work into one batch (amortizes slot bookkeeping,
                // completion locking and — through the backend's Batch verb
                // — the gateway round trip). The batch runs sequentially on
                // this worker under the single slot acquired by the pop
                // above.
                let mut tasks = vec![first];
                let max_batch = faas.engine.max_batch.load(Ordering::Relaxed).max(1);
                if max_batch > 1 {
                    let limit = faas.engine.per_resource_slots.load(Ordering::Relaxed).max(1);
                    let mut q = faas.engine.queue.lock().unwrap();
                    drain_same_resource(&mut q, rid, class, limit, max_batch, &mut tasks);
                }
                faas.run_batch(rid, tasks);
                {
                    let mut q = faas.engine.queue.lock().unwrap();
                    q.busy = q.busy.saturating_sub(1);
                    if let Some(n) = q.in_use.get_mut(&rid) {
                        *n = n.saturating_sub(1);
                        if *n == 0 {
                            q.in_use.remove(&rid);
                        }
                    }
                }
                faas.engine.queue_cv.notify_all();
            }
        }
    }
}

impl EdgeFaaS {
    /// Submit a workflow run with default QoS (`Interactive`, no deadline);
    /// returns immediately with its [`RunId`]. Entry functions fire at
    /// once; dependents fire as their dependencies complete, interleaved
    /// with every other in-flight run. See [`Self::submit_workflow_qos`]
    /// for the admission (backpressure) rules.
    pub fn submit_workflow(
        self: &Arc<Self>,
        app: &str,
        entry_inputs: &HashMap<String, Vec<String>>,
    ) -> Result<RunId, EngineError> {
        self.submit_workflow_qos(app, entry_inputs, QoS::default())
    }

    /// Submit a workflow run under an explicit [`QoS`].
    ///
    /// Admission control: if the pending-run bound or any entry resource's
    /// queued-instance bound ([`Self::set_backpressure`]) would be
    /// exceeded, `Realtime`/`Interactive` submissions first shed queued
    /// `Batch`-class runs (newest first, only runs with no instance
    /// currently executing; each shed run fails with a "shed under
    /// backpressure" message and publishes `RunCompleted { ok: false }`).
    /// If nothing can be shed — or the submission is itself `Batch` — the
    /// submission is refused with [`EngineError::Saturated`].
    pub fn submit_workflow_qos(
        self: &Arc<Self>,
        app: &str,
        entry_inputs: &HashMap<String, Vec<String>>,
        qos: QoS,
    ) -> Result<RunId, EngineError> {
        let application = self.app(app).map_err(|e| EngineError::Rejected(e.to_string()))?;
        // Entry-instance demand per resource (for the per-resource queue
        // bound). Placement errors are deliberately ignored here: such a
        // run is admitted and then fails through the normal fire path.
        let mut demand: HashMap<ResourceId, usize> = HashMap::new();
        for f in &application.config.entrypoints {
            for rid in self.candidates_of(app, f).unwrap_or_default() {
                *demand.entry(rid).or_insert(0) += 1;
            }
        }
        let max_runs = self.engine.max_pending_runs.load(Ordering::Relaxed).max(1);
        let max_queued = self.engine.max_queued_per_resource.load(Ordering::Relaxed).max(1);
        let mut events = Vec::new();
        let admitted: Result<RunId, EngineError> = {
            let mut runs = self.engine.runs.lock().unwrap();
            let admission = loop {
                let pending = runs.pending_runs;
                let saturated_resource = {
                    let q = self.engine.queue.lock().unwrap();
                    // Fast path: if the whole queue plus this run's largest
                    // per-resource demand fits the bound, no single
                    // resource can exceed it — skip the per-resource scan
                    // (it is O(queue), and it runs under both locks).
                    let total_queued = q.ready.len() + q.deferred.len();
                    let max_demand = demand.values().copied().max().unwrap_or(0);
                    if total_queued + max_demand <= max_queued {
                        None
                    } else {
                        demand
                            .iter()
                            .find(|(rid, d)| queued_on(&q, **rid) + **d > max_queued)
                            .map(|(rid, _)| *rid)
                    }
                };
                if pending < max_runs && saturated_resource.is_none() {
                    break Ok(());
                }
                // Shed only when it can actually relieve the binding
                // constraint: against the pending-run bound any queued
                // Batch run helps; against a saturated resource only Batch
                // runs queued *on that resource* do. A demand larger than
                // the per-resource bound can never be admitted, so nothing
                // is shed for it.
                let impossible = demand.values().any(|d| *d > max_queued);
                let shed_target = if pending >= max_runs { None } else { saturated_resource };
                if !impossible
                    && qos.priority != Priority::Batch
                    && self.shed_newest_queued_batch(&mut runs, shed_target, &mut events)
                {
                    continue;
                }
                break Err(EngineError::Saturated {
                    pending_runs: pending,
                    max_pending_runs: max_runs,
                    saturated_resource,
                    retry_after_s: SATURATED_RETRY_AFTER_S,
                });
            };
            match admission {
                Err(e) => Err(e),
                Ok(()) => {
                    let run = self.engine.next_run.fetch_add(1, Ordering::SeqCst);
                    let now = self.clock.now();
                    let entry = RunEntry {
                        app_name: app.to_string(),
                        app: Arc::clone(&application),
                        entry_inputs: entry_inputs.clone(),
                        state: RunState::new(&application.dag),
                        fired: HashSet::new(),
                        pending: HashMap::new(),
                        partial: HashMap::new(),
                        result: WorkflowResult::default(),
                        open_tasks: 0,
                        started: now,
                        qos,
                        deadline_abs: qos.deadline_s.map(|d| now + d.max(0.0)),
                        deadline_missed: false,
                        failed: None,
                        done: false,
                    };
                    // Insert before enqueueing so a fast worker finds it.
                    runs.map.insert(run, entry);
                    runs.pending_runs += 1;
                    let completed = {
                        let entry = runs.map.get_mut(&run).expect("just inserted");
                        let entrypoints = application.config.entrypoints.clone();
                        let mut batch = Vec::new();
                        for f in &entrypoints {
                            if let Err(e) = self.fire_node(run, entry, f, &mut batch) {
                                entry.failed.get_or_insert(e.to_string());
                                break;
                            }
                        }
                        self.engine.enqueue(batch);
                        self.check_done(run, entry, &mut events)
                    };
                    if completed {
                        Self::retire_finished(&mut runs, run);
                    }
                    Ok(run)
                }
            }
        };
        // Shed victims may already have wait_workflow callers parked.
        if events.iter().any(|e| matches!(e, EngineEvent::RunCompleted { .. })) {
            self.engine.done_cv.notify_all();
        }
        self.emit_events(&events);
        if admitted.is_ok() {
            self.ensure_workers();
        }
        admitted
    }

    /// Shed the newest `Batch`-class run that has no instance currently
    /// executing: its queued instances are removed from the ready/deferred
    /// queues and the run fails with a backpressure message. With
    /// `on_resource` set, only runs with at least one instance queued on
    /// that resource qualify — shedding a run that cannot relieve the
    /// saturated resource would destroy it for zero benefit. Returns false
    /// when no run qualifies. Caller holds the runs lock and collects the
    /// completion events.
    fn shed_newest_queued_batch(
        &self,
        runs: &mut RunTable,
        on_resource: Option<ResourceId>,
        events: &mut Vec<EngineEvent>,
    ) -> bool {
        let victim = {
            // Queue lock nested inside the runs lock — the same nesting
            // order as `enqueue` under `complete_batch`.
            let q = self.engine.queue.lock().unwrap();
            let mut queued_per_run: HashMap<RunId, usize> = HashMap::new();
            let mut on_rid: HashSet<RunId> = HashSet::new();
            for t in q.ready.values() {
                if let Task::Instance(ti) = t {
                    *queued_per_run.entry(ti.run).or_insert(0) += 1;
                    if Some(ti.resource) == on_resource {
                        on_rid.insert(ti.run);
                    }
                }
            }
            for t in q.deferred.values() {
                *queued_per_run.entry(t.run).or_insert(0) += 1;
                if Some(t.resource) == on_resource {
                    on_rid.insert(t.run);
                }
            }
            runs.map
                .iter()
                .filter(|(id, e)| {
                    !e.done
                        && e.qos.priority == Priority::Batch
                        && e.open_tasks > 0
                        && queued_per_run.get(*id).copied().unwrap_or(0) == e.open_tasks
                        && (on_resource.is_none() || on_rid.contains(*id))
                })
                .map(|(id, _)| *id)
                .max()
        };
        let Some(victim) = victim else { return false };
        {
            let mut q = self.engine.queue.lock().unwrap();
            let keys: Vec<QKey> = q
                .ready
                .iter()
                .filter(|(_, t)| matches!(t, Task::Instance(ti) if ti.run == victim))
                .map(|(k, _)| *k)
                .collect();
            for k in keys {
                q.ready.remove(&k);
            }
            let keys: Vec<QKey> =
                q.deferred.iter().filter(|(_, t)| t.run == victim).map(|(k, _)| *k).collect();
            for k in keys {
                q.deferred.remove(&k);
            }
        }
        let entry = runs.map.get_mut(&victim).expect("victim observed under this lock");
        entry.open_tasks = 0;
        entry.failed.get_or_insert_with(|| {
            "shed under backpressure (batch-class run evicted by a higher-priority submission)"
                .to_string()
        });
        log::warn!("engine saturated: shedding batch-class run {victim}");
        if self.check_done(victim, entry, events) {
            Self::retire_finished(runs, victim);
        }
        // A worker parked on the queue condvar may have been waiting for
        // exactly the tasks just removed: wake it to re-evaluate (it exits
        // if the queue is now empty).
        self.engine.queue_cv.notify_all();
        true
    }

    /// Block until a run completes (or `timeout_s` elapses; pass
    /// `f64::INFINITY` to wait forever). Consumes the run's record on
    /// completion. Each failure mode is a distinct [`WaitError`] variant:
    /// a wait timeout (the run is still executing and can be waited on
    /// again) is not a run failure, and a missed QoS deadline is reported
    /// as [`WaitError::DeadlineExceeded`] rather than a generic failure
    /// string.
    pub fn wait_workflow(&self, run: RunId, timeout_s: f64) -> Result<WorkflowResult, WaitError> {
        let deadline = if timeout_s.is_finite() {
            Some(
                std::time::Instant::now()
                    + std::time::Duration::from_secs_f64(timeout_s.max(0.0)),
            )
        } else {
            None
        };
        let mut runs = self.engine.runs.lock().unwrap();
        loop {
            let done = match runs.map.get(&run) {
                None => return Err(WaitError::UnknownRun { run }),
                Some(e) => e.done,
            };
            if done {
                let entry = runs.map.remove(&run).expect("checked above");
                if entry.deadline_missed {
                    return Err(WaitError::DeadlineExceeded { run });
                }
                return match entry.failed {
                    Some(message) => Err(WaitError::RunFailed { run, message }),
                    None => Ok(entry.result),
                };
            }
            match deadline {
                None => runs = self.engine.done_cv.wait(runs).unwrap(),
                Some(d) => {
                    let now = std::time::Instant::now();
                    if now >= d {
                        return Err(WaitError::Timeout { run, waited_s: timeout_s.max(0.0) });
                    }
                    let (g, _) = self.engine.done_cv.wait_timeout(runs, d - now).unwrap();
                    runs = g;
                }
            }
        }
    }

    /// Non-blocking peek at a run (None once consumed by `wait_workflow` /
    /// `take_run`).
    pub fn run_status(&self, run: RunId) -> Option<RunStatus> {
        let runs = self.engine.runs.lock().unwrap();
        runs.map.get(&run).map(Self::status_of)
    }

    /// Like [`Self::run_status`], but removes the record once the run is
    /// done (the REST gateway's poll-then-forget semantics).
    pub fn take_run(&self, run: RunId) -> Option<RunStatus> {
        let mut runs = self.engine.runs.lock().unwrap();
        let done = runs.map.get(&run)?.done;
        if !done {
            return Some(RunStatus::Running);
        }
        let entry = runs.map.remove(&run).expect("checked above");
        Some(if entry.deadline_missed {
            RunStatus::DeadlineExceeded
        } else if let Some(msg) = entry.failed {
            RunStatus::Failed(msg)
        } else {
            RunStatus::Done(entry.result)
        })
    }

    fn status_of(e: &RunEntry) -> RunStatus {
        if !e.done {
            RunStatus::Running
        } else if e.deadline_missed {
            RunStatus::DeadlineExceeded
        } else if let Some(msg) = &e.failed {
            RunStatus::Failed(msg.clone())
        } else {
            RunStatus::Done(e.result.clone())
        }
    }

    /// QoS class and deadline state of a run still in the table: the
    /// submitted [`QoS`] plus, when a deadline was set, the remaining
    /// budget in seconds (negative once past). `None` once the record has
    /// been consumed.
    pub fn run_qos(&self, run: RunId) -> Option<(QoS, Option<f64>)> {
        let runs = self.engine.runs.lock().unwrap();
        runs.map
            .get(&run)
            .map(|e| (e.qos, e.deadline_abs.map(|d| d - self.clock.now())))
    }

    /// Run an opaque job on the engine's worker pool (the async-invoke
    /// front-end; also usable for background coordinator chores).
    ///
    /// Jobs may themselves block on further engine progress (a nested
    /// `invoke_async`, a `run_workflow` issued from a background chore), so
    /// unlike instances they are never allowed to deadlock against the
    /// worker cap: when no free worker exists at submission time, one
    /// worker is spawned past `max_workers` — bounded by one thread per
    /// outstanding job, the same bound the old thread-per-async-invocation
    /// design had.
    pub fn spawn_job(self: &Arc<Self>, job: impl FnOnce(&Arc<EdgeFaaS>) + Send + 'static) {
        self.spawn_job_qos(QoS::default(), job)
    }

    /// [`Self::spawn_job`] under an explicit [`QoS`]: the class orders the
    /// job against every other queued task, and a deadline (if any) is an
    /// EDF ordering hint — jobs are opaque, so they are never
    /// deadline-cancelled and are not subject to run backpressure.
    pub fn spawn_job_qos(
        self: &Arc<Self>,
        qos: QoS,
        job: impl FnOnce(&Arc<EdgeFaaS>) + Send + 'static,
    ) {
        let deadline_ns = qos
            .deadline_s
            .map(|d| ((self.clock.now() + d.max(0.0)) * 1e9) as u64)
            .unwrap_or(u64::MAX);
        self.engine.enqueue(vec![Task::Job {
            class: qos.priority,
            deadline_ns,
            job: Box::new(job),
        }]);
        let overflow = {
            let mut q = self.engine.queue.lock().unwrap();
            if q.workers.saturating_sub(q.busy) == 0 {
                q.workers += 1;
                true
            } else {
                false
            }
        };
        if overflow {
            let faas = Arc::clone(self);
            let spawned = std::thread::Builder::new()
                .name("engine-worker".into())
                .spawn(move || engine_worker(faas));
            if spawned.is_err() {
                self.engine.queue.lock().unwrap().workers -= 1;
            }
        } else {
            self.ensure_workers();
        }
    }

    /// Subscribe to engine completion events. Callbacks run on worker
    /// threads after the engine's locks are released, so they may call back
    /// into the coordinator (e.g. `reschedule_function` on load changes).
    pub fn on_engine_event(&self, cb: impl Fn(&EdgeFaaS, &EngineEvent) + Send + Sync + 'static) {
        self.engine.callbacks.lock().unwrap().push(Arc::new(cb));
    }

    /// Tune the engine: worker-thread cap and per-resource admission slots
    /// (both clamped to >= 1). Takes effect for subsequent scheduling
    /// decisions.
    pub fn set_engine_limits(&self, max_workers: usize, per_resource_slots: usize) {
        self.engine.max_workers.store(max_workers.max(1), Ordering::Relaxed);
        self.engine.per_resource_slots.store(per_resource_slots.max(1), Ordering::Relaxed);
        self.engine.queue_cv.notify_all();
    }

    /// Toggle per-resource invocation batching (see the module docs).
    /// Enabled by default with [`DEFAULT_MAX_BATCH`]; disabling dispatches
    /// every instance individually. Batching on or off, runs produce
    /// identical firing orders and outputs — only the dispatch overhead
    /// changes.
    pub fn set_batching(&self, enabled: bool) {
        self.set_max_batch(if enabled { DEFAULT_MAX_BATCH } else { 1 });
    }

    /// Cap the per-resource invocation batch size (clamped to >= 1; 1
    /// disables batching).
    pub fn set_max_batch(&self, max_batch: usize) {
        self.engine.max_batch.store(max_batch.max(1), Ordering::Relaxed);
    }

    /// Whether per-resource invocation batching is currently enabled.
    pub fn batching_enabled(&self) -> bool {
        self.engine.max_batch.load(Ordering::Relaxed) > 1
    }

    /// Tune the backpressure bounds (both clamped to >= 1): total pending
    /// (not yet finished) runs, and queued instances per resource. Beyond
    /// either bound, submissions are refused with
    /// [`EngineError::Saturated`] — after `Batch`-class shedding for
    /// higher-class submissions (see [`Self::submit_workflow_qos`]).
    pub fn set_backpressure(&self, max_pending_runs: usize, max_queued_per_resource: usize) {
        self.engine.max_pending_runs.store(max_pending_runs.max(1), Ordering::Relaxed);
        self.engine
            .max_queued_per_resource
            .store(max_queued_per_resource.max(1), Ordering::Relaxed);
    }

    // ------------------------------------------------------------ internal --

    /// Fire one DAG node: route its inputs, record bookkeeping, and collect
    /// one task per placement instance into `batch`.
    ///
    /// Envelopes are assembled here, once per instance, into shared
    /// [`Bytes`]: the node-common `{"app":...,"function":...` head is
    /// serialized exactly once and shared across placements, and workers
    /// never rebuild or re-serialize a JSON tree on the dispatch path. Key
    /// order (`app`, `function`, `inputs`, `resource`) matches the sorted
    /// order [`Json`] serialization used, so the wire format is unchanged.
    fn fire_node(
        &self,
        run: RunId,
        entry: &mut RunEntry,
        fname: &str,
        batch: &mut Vec<Task>,
    ) -> anyhow::Result<()> {
        if !entry.fired.insert(fname.to_string()) {
            return Ok(());
        }
        let app = entry.app_name.clone();
        let placements = self.candidates_of(&app, fname)?;
        if placements.is_empty() {
            anyhow::bail!("function `{app}.{fname}` has no placements");
        }
        let per_instance =
            self.route_inputs(&app, fname, &placements, &entry.entry_inputs, &entry.result)?;
        entry.result.firing_order.push(fname.to_string());
        entry.pending.insert(fname.to_string(), placements.len());
        entry.partial.insert(fname.to_string(), vec![None; placements.len()]);
        entry.open_tasks += placements.len();
        let class = entry.qos.priority;
        let deadline_ns =
            entry.deadline_abs.map(|d| (d.max(0.0) * 1e9) as u64).unwrap_or(u64::MAX);
        // Serialize the node-common envelope head once (JSON-escaped).
        let mut head = String::with_capacity(32 + app.len() + fname.len());
        head.push_str("{\"app\":");
        head.push_str(&Json::Str(app.clone()).to_string());
        head.push_str(",\"function\":");
        head.push_str(&Json::Str(fname.to_string()).to_string());
        for (i, (rid, inputs)) in placements.into_iter().zip(per_instance).enumerate() {
            let inputs_json = Json::Arr(inputs.into_iter().map(Json::Str).collect()).to_string();
            let mut env = String::with_capacity(head.len() + inputs_json.len() + 24);
            env.push_str(&head);
            env.push_str(",\"inputs\":");
            env.push_str(&inputs_json);
            env.push_str(",\"resource\":");
            env.push_str(&(rid as u64).to_string());
            env.push('}');
            batch.push(Task::Instance(InstanceTask {
                run,
                app: app.clone(),
                function: fname.to_string(),
                instance: i,
                resource: rid,
                class,
                deadline_ns,
                envelope: Bytes::from(env),
            }));
        }
        Ok(())
    }

    /// Execute a drained same-resource batch and fan the results back out
    /// to their runs. A batch of one takes the exact single-instance path;
    /// larger batches go through the backend's `Batch` verb
    /// ([`super::handle::ResourceHandle::invoke_batch`]) — one gateway
    /// round trip, per-entry failure containment, results in task order.
    fn run_batch(self: &Arc<Self>, rid: ResourceId, tasks: Vec<InstanceTask>) {
        // Fast-drain instances of runs that already failed or finished
        // (one lock for the whole batch). Like the unbatched path — where
        // siblings already executing on other workers cannot be recalled
        // either — this check is best-effort: a run failing mid-batch
        // wastes at most the remainder of this one batch.
        //
        // Deadline enforcement lives here too: an instance dispatched after
        // its run's deadline has passed is skipped instead of occupying the
        // backend, the run transitions to `DeadlineExceeded` (once), and
        // `EngineEvent::DeadlineMissed` fires for reschedule policies.
        let now = self.clock.now();
        let mut deadline_events = Vec::new();
        let skip: Vec<bool> = {
            let mut runs = self.engine.runs.lock().unwrap();
            tasks
                .iter()
                .map(|t| {
                    let Some(e) = runs.map.get_mut(&t.run) else { return true };
                    if e.failed.is_some() || e.done {
                        return true;
                    }
                    match e.deadline_abs {
                        Some(d) if now >= d => {
                            e.deadline_missed = true;
                            e.failed = Some(format!(
                                "deadline exceeded: dispatched {:.3}s past the {:.3}s deadline",
                                now - d,
                                e.qos.deadline_s.unwrap_or(0.0)
                            ));
                            deadline_events.push(EngineEvent::DeadlineMissed {
                                run: t.run,
                                app: e.app_name.clone(),
                                deadline_s: e.qos.deadline_s.unwrap_or(0.0),
                                late_by: now - d,
                            });
                            true
                        }
                        _ => false,
                    }
                })
                .collect()
        };
        self.emit_events(&deadline_events);
        let mut outcomes: Vec<Option<anyhow::Result<InstanceResult>>> =
            skip.iter().map(|_| None).collect();
        let live: Vec<usize> = (0..tasks.len()).filter(|&i| !skip[i]).collect();
        match live.len() {
            0 => {}
            1 => {
                let i = live[0];
                outcomes[i] = Some(run_instance(self, &tasks[i]));
            }
            _ => match self.resource(rid) {
                Err(e) => {
                    let msg = e.to_string();
                    for &i in &live {
                        outcomes[i] = Some(Err(anyhow::anyhow!(msg.clone())));
                    }
                }
                Ok(reg) => {
                    // Refcount bumps only: the envelopes were built at fire
                    // time and are shared with the backend call.
                    let calls: Vec<(String, Bytes)> = live
                        .iter()
                        .map(|&i| {
                            let t = &tasks[i];
                            (EdgeFaaS::qualified(&t.app, &t.function), t.envelope.clone())
                        })
                        .collect();
                    let invoked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        reg.handle.invoke_batch(&calls)
                    }));
                    match invoked {
                        Ok(results) => {
                            // Enforce the one-result-per-call contract: a
                            // misbehaving handle returning too few results
                            // must fail the unmatched tasks loudly, not
                            // strand them as "skipped" (which would wedge
                            // the run's pending count forever).
                            let mut results = results.into_iter();
                            for &i in &live {
                                outcomes[i] = Some(match results.next() {
                                    Some(result) => result.and_then(|(out, latency)| {
                                        Ok(InstanceResult {
                                            resource: rid,
                                            outputs: parse_outputs(&out)?,
                                            latency,
                                        })
                                    }),
                                    None => Err(anyhow::anyhow!(
                                        "backend returned too few batch results"
                                    )),
                                });
                            }
                        }
                        Err(payload) => {
                            // Only a handle without per-entry containment
                            // can unwind to here; fail the whole batch.
                            let what = crate::util::panic_message(&*payload);
                            for &i in &live {
                                outcomes[i] = Some(Err(anyhow::anyhow!(
                                    "function handler panicked: {what}"
                                )));
                            }
                        }
                    }
                }
            },
        }
        self.complete_batch(&tasks, outcomes);
    }

    /// Process a batch of finished (or skipped) instances, sequentially in
    /// task order — exactly the bookkeeping N single completions would do,
    /// but with the run-table lock taken twice per batch instead of twice
    /// per task.
    ///
    /// Two lock phases with the node-completion events emitted *between*
    /// them: subscribers observe `NodeCompleted` before the node's
    /// dependents are scheduled, so a callback (e.g. one invoking
    /// `reschedule_function` against fresh monitoring data) can still
    /// influence where the next stage lands.
    fn complete_batch(
        self: &Arc<Self>,
        tasks: &[InstanceTask],
        outcomes: Vec<Option<anyhow::Result<InstanceResult>>>,
    ) {
        // Phase 1: record every instance; detect node completions.
        let mut node_events = Vec::new();
        let mut node_done = vec![false; tasks.len()];
        {
            let mut runs = self.engine.runs.lock().unwrap();
            for ((idx, task), outcome) in tasks.iter().enumerate().zip(outcomes) {
                let Some(entry) = runs.map.get_mut(&task.run) else { continue };
                entry.open_tasks = entry.open_tasks.saturating_sub(1);
                match outcome {
                    None => {} // skipped: the run had already failed
                    Some(Ok(r)) => {
                        if entry.failed.is_none() {
                            if let Some(slots) = entry.partial.get_mut(&task.function) {
                                slots[task.instance] = Some(r);
                            }
                            node_done[idx] = match entry.pending.get_mut(&task.function) {
                                Some(p) => {
                                    *p -= 1;
                                    *p == 0
                                }
                                None => false,
                            };
                            if node_done[idx] {
                                entry.pending.remove(&task.function);
                                let slots =
                                    entry.partial.remove(&task.function).unwrap_or_default();
                                let instances: Vec<InstanceResult> =
                                    slots.into_iter().flatten().collect();
                                let latency =
                                    instances.iter().map(|i| i.latency).fold(0.0, f64::max);
                                node_events.push(EngineEvent::NodeCompleted {
                                    run: task.run,
                                    app: entry.app_name.clone(),
                                    function: task.function.clone(),
                                    instances: instances.len(),
                                    latency,
                                });
                                entry.result.functions.insert(task.function.clone(), instances);
                            }
                        }
                    }
                    Some(Err(e)) => {
                        let msg = format!(
                            "workflow `{}` function `{}` on resource {}: {e}",
                            entry.app_name, task.function, task.resource
                        );
                        log::warn!("{msg}");
                        entry.failed.get_or_insert(msg);
                        entry.pending.remove(&task.function);
                        entry.partial.remove(&task.function);
                    }
                }
            }
        }
        self.emit_events(&node_events);

        // Phase 2: fire newly-ready dependents (sorted by topological index
        // for deterministic firing orders) in task order so firing orders
        // match unbatched execution — for EVERY completed node in the batch
        // before any run-completion check. Two batch entries can belong to
        // one run, and `check_done` treats `open_tasks == 0` as
        // run-complete: checking an earlier entry's run before a later
        // entry fired its dependents would retire the run with downstream
        // nodes unfired. (The unbatched path kept this invariant implicitly
        // by interleaving fire and check per instance.)
        let mut run_events = Vec::new();
        {
            let mut runs = self.engine.runs.lock().unwrap();
            let mut to_enqueue = Vec::new();
            for (idx, task) in tasks.iter().enumerate() {
                if !node_done[idx] {
                    continue;
                }
                let Some(entry) = runs.map.get_mut(&task.run) else { continue };
                if entry.failed.is_some() {
                    continue;
                }
                let application = Arc::clone(&entry.app);
                let mut ready = entry.state.complete(&application.dag, &task.function);
                ready.sort_by_key(|n| {
                    application.dag.topo_order.iter().position(|x| x == n).unwrap_or(usize::MAX)
                });
                for f in &ready {
                    if let Err(e) = self.fire_node(task.run, entry, f, &mut to_enqueue) {
                        entry.failed.get_or_insert(e.to_string());
                        break;
                    }
                }
            }
            // Now detect run completions (idempotent per run via the `done`
            // flag, so duplicate runs in one batch check harmlessly twice).
            for task in tasks {
                let completed = match runs.map.get_mut(&task.run) {
                    None => false,
                    Some(entry) => self.check_done(task.run, entry, &mut run_events),
                };
                if completed {
                    Self::retire_finished(&mut runs, task.run);
                }
            }
            // One enqueue (queue lock + wakeup) for the whole batch.
            self.engine.enqueue(to_enqueue);
        }
        if run_events.iter().any(|e| matches!(e, EngineEvent::RunCompleted { .. })) {
            self.engine.done_cv.notify_all();
        }
        self.emit_events(&run_events);
        self.ensure_workers();
    }

    /// Mark a drained run done; returns true on the completing transition.
    fn check_done(&self, run: RunId, entry: &mut RunEntry, events: &mut Vec<EngineEvent>) -> bool {
        if !entry.done && entry.open_tasks == 0 {
            entry.done = true;
            entry.result.duration = self.clock.now() - entry.started;
            events.push(EngineEvent::RunCompleted {
                run,
                app: entry.app_name.clone(),
                ok: entry.failed.is_none(),
                duration: entry.result.duration,
            });
            return true;
        }
        false
    }

    /// Record a just-completed run in the retention queue, evicting the
    /// oldest completed-but-unconsumed runs beyond [`MAX_FINISHED_RUNS`].
    /// (Runs consumed by `wait_workflow`/`take_run` leave stale ids behind;
    /// those pop harmlessly here.) Called exactly once per completing
    /// transition (`check_done` returning true), so it also settles the
    /// pending-run counter.
    fn retire_finished(runs: &mut RunTable, run: RunId) {
        runs.pending_runs = runs.pending_runs.saturating_sub(1);
        while runs.finished.len() >= MAX_FINISHED_RUNS {
            let Some(old) = runs.finished.pop_front() else { break };
            if runs.map.get(&old).map(|e| e.done).unwrap_or(false) {
                runs.map.remove(&old);
            }
        }
        runs.finished.push_back(run);
    }

    fn emit_events(&self, events: &[EngineEvent]) {
        if events.is_empty() {
            return;
        }
        let cbs: Vec<EventCallback> = self.engine.callbacks.lock().unwrap().clone();
        for ev in events {
            for cb in &cbs {
                cb(self, ev);
            }
        }
    }

    /// Spawn worker threads up to the cap, one per pending task. Workers
    /// exit when the queue drains, so an idle coordinator holds no threads.
    fn ensure_workers(self: &Arc<Self>) {
        loop {
            {
                let mut q = self.engine.queue.lock().unwrap();
                let limit = self.engine.per_resource_slots.load(Ordering::Relaxed).max(1);
                // Admission-blocked deferred instances are not runnable
                // demand — a thread spawned for them could only park on the
                // condvar until a slot frees (and an existing worker will
                // pick them up then).
                let admissible_deferred = q
                    .deferred
                    .values()
                    .filter(|t| q.in_use.get(&t.resource).copied().unwrap_or(0) < limit)
                    .count();
                let pending = q.ready.len() + admissible_deferred;
                let max = self.engine.max_workers.load(Ordering::Relaxed).max(1);
                // Compare the backlog against *free* capacity: workers stuck
                // in a long task must not stop a short run from getting a
                // fresh thread (no head-of-line blocking across runs).
                let available = q.workers.saturating_sub(q.busy);
                if q.workers >= max || available >= pending {
                    return;
                }
                q.workers += 1;
            }
            let faas = Arc::clone(self);
            let spawned = std::thread::Builder::new()
                .name("engine-worker".into())
                .spawn(move || engine_worker(faas));
            if spawned.is_err() {
                self.engine.queue.lock().unwrap().workers -= 1;
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::functions::FunctionPackage;
    use crate::simnet::{RealClock, VirtualClock};
    use crate::testbed::{paper_testbed, TestBed};
    use std::sync::atomic::AtomicUsize;

    /// A two-stage chain app: `gen` on the first two Pis -> `sum` on an
    /// edge, with counting handlers that thread a run tag through object
    /// URLs so concurrent runs are distinguishable.
    fn chain_bed(clock: Arc<dyn crate::simnet::Clock>) -> TestBed {
        let b = paper_testbed(clock);
        let faas = Arc::clone(&b.faas);
        let yaml = "\
application: chain
entrypoint: gen
dag:
  - name: gen
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
  - name: sum
    dependencies: gen
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: 1
";
        let mut data = HashMap::new();
        data.insert("gen".to_string(), vec![b.iot[0], b.iot[1]]);
        faas.configure_application(yaml, &data).unwrap();
        faas.create_bucket("chain", "work", Some(b.edges[0])).unwrap();
        {
            let faas = Arc::clone(&faas);
            b.executor.register("img/gen", move |payload: &[u8]| {
                let v = crate::util::json::parse(std::str::from_utf8(payload)?)?;
                let rid = v.get("resource").unwrap().as_u64().unwrap();
                // Entry inputs carry the run tag (one URL-ish string).
                let tag = v
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .and_then(|a| a.first())
                    .and_then(Json::as_str)
                    .unwrap_or("r?")
                    .rsplit('/')
                    .next()
                    .unwrap_or("r?")
                    .to_string();
                let obj = format!("{tag}-gen-{rid}.bin");
                let url = faas.put_object("chain", "work", &obj, tag.as_bytes())?;
                let mut out = Json::obj();
                out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
                Ok(out.to_string().into_bytes())
            });
        }
        {
            let faas = Arc::clone(&faas);
            b.executor.register("img/sum", move |payload: &[u8]| {
                let v = crate::util::json::parse(std::str::from_utf8(payload)?)?;
                let inputs = v.get("inputs").and_then(Json::as_arr).unwrap_or(&[]).to_vec();
                let mut tags: Vec<String> = Vec::new();
                for u in &inputs {
                    let data = faas.get_object_url(u.as_str().unwrap())?;
                    tags.push(String::from_utf8_lossy(&data).to_string());
                }
                tags.sort();
                tags.dedup();
                anyhow::ensure!(tags.len() == 1, "inputs from mixed runs: {tags:?}");
                let obj = format!("{}-sum-n{}.bin", tags[0], inputs.len());
                let url = faas.put_object("chain", "work", &obj, tags[0].as_bytes())?;
                let mut out = Json::obj();
                out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
                Ok(out.to_string().into_bytes())
            });
        }
        faas.deploy_function("chain", "gen", &FunctionPackage { code: "img/gen".into() })
            .unwrap();
        faas.deploy_function("chain", "sum", &FunctionPackage { code: "img/sum".into() })
            .unwrap();
        b
    }

    fn entry_for(run_tag: &str) -> HashMap<String, Vec<String>> {
        // Two pseudo-URL entry inputs; routing sends one to each gen
        // instance (parsing requires app/bucket/rid/object shape).
        let mut m = HashMap::new();
        m.insert(
            "gen".to_string(),
            vec![format!("chain/work/0/{run_tag}"), format!("chain/work/1/{run_tag}")],
        );
        m
    }

    #[test]
    fn submit_then_wait_runs_the_dag() {
        let b = chain_bed(Arc::new(RealClock::new()));
        let run = b.faas.submit_workflow("chain", &entry_for("r0")).unwrap();
        let result = b.faas.wait_workflow(run, 10.0).unwrap();
        assert_eq!(result.firing_order, vec!["gen", "sum"]);
        assert_eq!(result.functions["gen"].len(), 2);
        assert_eq!(result.functions["sum"].len(), 1);
        assert!(result.functions["sum"][0].outputs[0].contains("r0-sum-n2"));
        // The record was consumed.
        assert!(b.faas.run_status(run).is_none());
        assert!(b.faas.wait_workflow(run, 0.1).is_err());
    }

    #[test]
    fn concurrent_runs_interleave_and_stay_isolated() {
        for clock in [
            Arc::new(RealClock::new()) as Arc<dyn crate::simnet::Clock>,
            Arc::new(VirtualClock::new()) as Arc<dyn crate::simnet::Clock>,
        ] {
            let b = chain_bed(clock);
            let runs: Vec<(String, RunId)> = (0..6)
                .map(|i| {
                    let tag = format!("r{i}");
                    let id = b.faas.submit_workflow("chain", &entry_for(&tag)).unwrap();
                    (tag, id)
                })
                .collect();
            for (tag, id) in runs {
                let result = b.faas.wait_workflow(id, 30.0).unwrap();
                let out = &result.functions["sum"][0].outputs[0];
                assert!(
                    out.contains(&format!("{tag}-sum-n2")),
                    "run {tag} got cross-contaminated: {out}"
                );
                assert_eq!(result.firing_order, vec!["gen", "sum"]);
            }
        }
    }

    #[test]
    fn batching_on_and_off_produce_identical_results() {
        for enabled in [false, true] {
            let b = chain_bed(Arc::new(RealClock::new()));
            b.faas.set_batching(enabled);
            assert_eq!(b.faas.batching_enabled(), enabled);
            // One admission slot per resource forces queuing, so the
            // batched pass actually forms multi-task batches.
            b.faas.set_engine_limits(8, 1);
            let runs: Vec<(String, RunId)> = (0..6)
                .map(|i| {
                    let tag = format!("r{i}");
                    let id = b.faas.submit_workflow("chain", &entry_for(&tag)).unwrap();
                    (tag, id)
                })
                .collect();
            for (tag, id) in runs {
                let result = b.faas.wait_workflow(id, 30.0).unwrap();
                assert_eq!(result.firing_order, vec!["gen", "sum"], "batching={enabled}");
                let out = &result.functions["sum"][0].outputs[0];
                assert!(
                    out.contains(&format!("{tag}-sum-n2")),
                    "batching={enabled}: run {tag} contaminated: {out}"
                );
            }
        }
    }

    #[test]
    fn per_resource_admission_limit_is_enforced() {
        let b = chain_bed(Arc::new(RealClock::new()));
        b.faas.set_engine_limits(16, 1);
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        {
            let (live, peak) = (Arc::clone(&live), Arc::clone(&peak));
            b.executor.register("img/busy", move |_: &[u8]| {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(20));
                live.fetch_sub(1, Ordering::SeqCst);
                Ok(br#"{"outputs":[]}"#.to_vec())
            });
        }
        // A single-function app pinned to one Pi.
        let yaml = "\
application: busy
entrypoint: f
dag:
  - name: f
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
";
        let mut data = HashMap::new();
        data.insert("f".to_string(), vec![b.iot[0]]);
        b.faas.configure_application(yaml, &data).unwrap();
        b.faas.deploy_function("busy", "f", &FunctionPackage { code: "img/busy".into() }).unwrap();
        let ids: Vec<RunId> = (0..5)
            .map(|_| b.faas.submit_workflow("busy", &HashMap::new()).unwrap())
            .collect();
        for id in ids {
            b.faas.wait_workflow(id, 30.0).unwrap();
        }
        assert_eq!(
            peak.load(Ordering::SeqCst),
            1,
            "admission limit of 1 must serialize instances on the resource"
        );
    }

    #[test]
    fn events_fire_and_allow_midrun_rescheduling() {
        let b = chain_bed(Arc::new(RealClock::new()));
        let nodes = Arc::new(Mutex::new(Vec::<String>::new()));
        let runs_done = Arc::new(AtomicUsize::new(0));
        // Mid-run reaction: when `gen` completes, migrate `sum` to the other
        // edge before it fires (the reschedule_function hook point).
        let target = b.edges[1];
        b.faas
            .resource(target)
            .unwrap()
            .handle
            .deploy("chain.sum", "img/sum", 128 << 20, 0, &[])
            .unwrap();
        {
            let nodes = Arc::clone(&nodes);
            let runs_done = Arc::clone(&runs_done);
            b.faas.on_engine_event(move |faas, ev| match ev {
                EngineEvent::NodeCompleted { function, .. } => {
                    nodes.lock().unwrap().push(function.clone());
                    if function == "gen" {
                        faas.set_candidates("chain", "sum", vec![target]).unwrap();
                    }
                }
                EngineEvent::RunCompleted { ok, .. } => {
                    assert!(ok);
                    runs_done.fetch_add(1, Ordering::SeqCst);
                }
                EngineEvent::DeadlineMissed { .. } => unreachable!("no deadlines set"),
            });
        }
        let run = b.faas.submit_workflow("chain", &entry_for("ev")).unwrap();
        let result = b.faas.wait_workflow(run, 10.0).unwrap();
        assert_eq!(result.functions["sum"][0].resource, target, "sum moved mid-run");
        assert_eq!(*nodes.lock().unwrap(), vec!["gen", "sum"]);
        assert_eq!(runs_done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn failed_stage_surfaces_the_handler_error() {
        let b = chain_bed(Arc::new(RealClock::new()));
        b.executor.register("img/sum", |_: &[u8]| anyhow::bail!("sum exploded"));
        let bad = b.faas.submit_workflow("chain", &entry_for("bad")).unwrap();
        let err = b.faas.wait_workflow(bad, 10.0).unwrap_err().to_string();
        assert!(err.contains("sum exploded"), "{err}");
    }

    #[test]
    fn unknown_app_and_unknown_run_error() {
        let b = chain_bed(Arc::new(RealClock::new()));
        assert!(b.faas.submit_workflow("ghost", &HashMap::new()).is_err());
        assert_eq!(
            b.faas.wait_workflow(999_999, 0.05).unwrap_err(),
            WaitError::UnknownRun { run: 999_999 }
        );
        assert!(b.faas.run_status(999_999).is_none());
    }

    // ------------------------------------------------- queue-order units --

    fn inst(run: RunId, rid: ResourceId, class: Priority, deadline_ns: u64) -> Task {
        Task::Instance(InstanceTask {
            run,
            app: "a".into(),
            function: "f".into(),
            instance: 0,
            resource: rid,
            class,
            deadline_ns,
            envelope: Bytes::new(),
        })
    }

    fn fresh_queue() -> QueueState {
        QueueState {
            ready: std::collections::BTreeMap::new(),
            deferred: std::collections::BTreeMap::new(),
            in_use: HashMap::new(),
            next_seq: 0,
            since_batch: 0,
            workers: 0,
            busy: 0,
        }
    }

    fn push(q: &mut QueueState, t: Task) {
        let key = QKey { class: t.class().rank(), deadline_ns: t.deadline_ns(), seq: q.next_seq };
        q.next_seq += 1;
        q.ready.insert(key, t);
    }

    /// Pop one task and release its admission slot (simulates instant
    /// completion so admission never interferes with order checks).
    fn pop_run(q: &mut QueueState) -> RunId {
        match pop_task(q, 8) {
            Popped::Task(Task::Instance(t)) => {
                if let Some(n) = q.in_use.get_mut(&t.resource) {
                    *n = n.saturating_sub(1);
                }
                t.run
            }
            _ => panic!("expected an instance"),
        }
    }

    #[test]
    fn pop_orders_by_class_then_deadline_then_submission() {
        let mut q = fresh_queue();
        // Submission order: batch, interactive (late deadline), realtime,
        // interactive (early deadline), interactive (no deadline).
        push(&mut q, inst(0, 0, Priority::Batch, u64::MAX));
        push(&mut q, inst(1, 1, Priority::Interactive, 2_000_000_000));
        push(&mut q, inst(2, 2, Priority::Realtime, u64::MAX));
        push(&mut q, inst(3, 3, Priority::Interactive, 1_000_000_000));
        push(&mut q, inst(4, 4, Priority::Interactive, u64::MAX));
        // Class first (realtime), then EDF within interactive (run 3 before
        // run 1), no-deadline interactive last of its class, batch last.
        assert_eq!(pop_run(&mut q), 2, "realtime jumps the queue");
        assert_eq!(pop_run(&mut q), 3, "earliest deadline first");
        assert_eq!(pop_run(&mut q), 1);
        assert_eq!(pop_run(&mut q), 4, "no deadline sorts after deadlines");
        assert_eq!(pop_run(&mut q), 0, "batch drains last");
        assert!(matches!(pop_task(&mut q, 8), Popped::Empty));
    }

    #[test]
    fn same_key_fields_fall_back_to_submission_order() {
        let mut q = fresh_queue();
        for run in 0..5 {
            push(&mut q, inst(run, run as ResourceId, Priority::Interactive, u64::MAX));
        }
        for run in 0..5 {
            assert_eq!(pop_run(&mut q), run, "FIFO within identical class/deadline");
        }
    }

    #[test]
    fn aging_guard_dispatches_batch_after_the_limit() {
        let mut q = fresh_queue();
        // One batch task waits while a steady interactive stream arrives.
        push(&mut q, inst(1000, 99, Priority::Batch, u64::MAX));
        for i in 0..(2 * BATCH_AGE_LIMIT) {
            push(&mut q, inst(i, i as ResourceId, Priority::Interactive, u64::MAX));
        }
        let mut pops_before_batch = 0u64;
        loop {
            let run = pop_run(&mut q);
            if run == 1000 {
                break;
            }
            pops_before_batch += 1;
            // Keep the stream topped up so strict priority alone would
            // starve the batch task forever.
            push(&mut q, inst(5000 + pops_before_batch, 7, Priority::Interactive, u64::MAX));
            assert!(
                pops_before_batch <= BATCH_AGE_LIMIT,
                "batch task starved past the aging limit"
            );
        }
        assert_eq!(
            pops_before_batch, BATCH_AGE_LIMIT,
            "batch dispatches exactly at the aging threshold"
        );
    }

    #[test]
    fn deadline_exceeded_run_fails_without_executing() {
        let b = chain_bed(Arc::new(RealClock::new()));
        let missed = Arc::new(AtomicUsize::new(0));
        {
            let missed = Arc::clone(&missed);
            b.faas.on_engine_event(move |_, ev| {
                if let EngineEvent::DeadlineMissed { deadline_s, late_by, .. } = ev {
                    assert_eq!(*deadline_s, 0.0);
                    assert!(*late_by >= 0.0);
                    missed.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
        // A deadline of zero is already past at first dispatch.
        let run = b
            .faas
            .submit_workflow_qos(
                "chain",
                &entry_for("dl"),
                QoS::class(Priority::Interactive).with_deadline(0.0),
            )
            .unwrap();
        let err = b.faas.wait_workflow(run, 10.0).unwrap_err();
        assert_eq!(err, WaitError::DeadlineExceeded { run });
        assert_eq!(missed.load(Ordering::SeqCst), 1, "DeadlineMissed fires once");
    }

    #[test]
    fn backpressure_saturates_and_sheds_batch_first() {
        let b = chain_bed(Arc::new(RealClock::new()));
        // One worker, one slot, no batching: the first popped instance
        // occupies the engine while the gate holds (a drain would pull the
        // other runs' iot-0 instances into its batch and make them
        // ineligible for shedding), so queue state is deterministic.
        b.faas.set_engine_limits(1, 1);
        b.faas.set_batching(false);
        b.faas.set_backpressure(3, 1024);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        {
            let gate = Arc::clone(&gate);
            b.executor.register("img/gen", move |_: &[u8]| {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
                Ok(br#"{"outputs":[]}"#.to_vec())
            });
        }
        b.executor.register("img/sum", |_: &[u8]| Ok(br#"{"outputs":[]}"#.to_vec()));
        let batch_qos = QoS::class(Priority::Batch);
        let b0 = b.faas.submit_workflow_qos("chain", &entry_for("b0"), batch_qos).unwrap();
        let b1 = b.faas.submit_workflow_qos("chain", &entry_for("b1"), batch_qos).unwrap();
        let b2 = b.faas.submit_workflow_qos("chain", &entry_for("b2"), batch_qos).unwrap();
        // 3 pending batch runs: a 4th batch submission is refused...
        match b.faas.submit_workflow_qos("chain", &entry_for("b3"), batch_qos) {
            Err(EngineError::Saturated { pending_runs, max_pending_runs, .. }) => {
                assert_eq!((pending_runs, max_pending_runs), (3, 3));
            }
            other => panic!("expected Saturated, got {other:?}"),
        }
        // ...but an interactive submission sheds the newest fully-queued
        // batch run (b2; b0 has an instance executing behind the gate).
        let rt = b
            .faas
            .submit_workflow_qos("chain", &entry_for("i0"), QoS::default())
            .unwrap();
        let err = b.faas.wait_workflow(b2, 10.0).unwrap_err();
        match err {
            WaitError::RunFailed { run, message } => {
                assert_eq!(run, b2);
                assert!(message.contains("shed under backpressure"), "{message}");
            }
            other => panic!("expected shed failure, got {other:?}"),
        }
        // Release the gate: the survivors all complete.
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        for id in [b0, b1, rt] {
            b.faas.wait_workflow(id, 30.0).unwrap();
        }
    }
}
