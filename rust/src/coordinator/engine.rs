//! The event-driven execution engine (the dispatch core behind every
//! invocation front-end).
//!
//! The paper positions EdgeFaaS "in the critical-path, acting like a
//! router" for every invocation (§3.2.1). This module is that router's
//! execution core: a run queue of in-flight workflow runs whose DAG nodes
//! fire as dependency-completion events, executed by a shared worker pool
//! under per-resource admission limits. Both invocation front-ends sit on
//! top of it:
//!
//! * synchronous [`EdgeFaaS::run_workflow`] = [`EdgeFaaS::submit_workflow`]
//!   + [`EdgeFaaS::wait_workflow`];
//! * asynchronous `invoke_async` = [`EdgeFaaS::spawn_job`] + tracker id
//!   (see [`super::asyncinvoke`]).
//!
//! The engine is generic over the [`crate::simnet::Clock`] the coordinator
//! was built with: under a `RealClock` the worker pool gives true wall-clock
//! parallelism; under a `VirtualClock` the same code path advances virtual
//! time (the benches' mode). Readiness is decided by dependency completion
//! with ready sets sorted by topological index, so chain-shaped DAGs (both
//! paper workflows) fire in the same order under either clock; independent
//! parallel branches may interleave by completion timing.
//!
//! Scheduling decisions interleave across runs: N submitted workflows share
//! the worker pool and the per-resource slots, so a long run does not
//! head-of-line-block a short one. Every node/run completion is also
//! published to [`EdgeFaaS::on_engine_event`] subscribers, which is the hook
//! `reschedule_function` reacts through mid-run.
//!
//! # Hot path & batching
//!
//! The paper puts EdgeFaaS "in the critical-path, acting like a router"
//! for every invocation, so per-invocation overhead bounds system
//! throughput. Two optimizations keep that overhead flat:
//!
//! * **Zero-copy envelopes.** A node's invocation envelope is assembled at
//!   fire time, once per instance, into a shared [`Bytes`] buffer: the
//!   `{"app":...,"function":...` head is serialized exactly once per node
//!   and shared across all placements, and only the per-instance
//!   `inputs`/`resource` tail is appended per placement. Workers and the
//!   batch protocol clone refcounts, never payload bytes, and handler
//!   outputs travel back the same way.
//!
//! * **Per-resource invocation batching.** When a worker acquires a
//!   resource's admission slot it opportunistically drains other queued
//!   instances bound for the *same* resource — admission-deferred ones
//!   always, ready-queue ones only while the resource is saturated
//!   (draining below the admission limit would trade away parallelism an
//!   idle worker could provide) — up to [`DEFAULT_MAX_BATCH`] — and
//!   executes them as one batch: a single
//!   admission-slot acquisition, one backend `Batch` round trip
//!   ([`super::handle::ResourceHandle::invoke_batch`]; per-task fallback for
//!   backends without the verb), and one amortized completion pass that
//!   takes the run-table lock twice per *batch* instead of twice per task.
//!   A batch executes sequentially on one worker, so the per-resource
//!   concurrency bound is unchanged, and results fan back out to their runs
//!   in pop order — the exact order a lone worker would have produced —
//!   preserving the determinism guarantee (identical firing orders/outputs
//!   under `RealClock` and `VirtualClock`, batching on or off). Toggle with
//!   [`EdgeFaaS::set_batching`] / [`EdgeFaaS::set_max_batch`]; measured by
//!   `benches/ablation_concurrency.rs` (`BENCH_hotpath.json`).

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::util::bytes::Bytes;
use crate::util::json::Json;

use super::dag::RunState;
use super::invoker::{parse_outputs, InstanceResult, WorkflowResult};
use super::resource::{Application, EdgeFaaS, ResourceId};

/// Identifier of one submitted workflow run.
pub type RunId = u64;

/// Externally visible state of a run.
#[derive(Debug, Clone)]
pub enum RunStatus {
    Running,
    Done(WorkflowResult),
    Failed(String),
}

/// A completion event published to [`EdgeFaaS::on_engine_event`] callbacks.
#[derive(Debug, Clone)]
pub enum EngineEvent {
    /// Every instance of one DAG node finished.
    NodeCompleted {
        run: RunId,
        app: String,
        function: String,
        /// Number of placement instances that executed.
        instances: usize,
        /// Slowest instance latency, seconds.
        latency: f64,
    },
    /// A whole run drained (successfully or not).
    RunCompleted { run: RunId, app: String, ok: bool, duration: f64 },
}

/// One schedulable unit: a single placement instance of a DAG node, or an
/// opaque job (the async-invoke front-end).
enum Task {
    Instance(InstanceTask),
    Job(Box<dyn FnOnce(&Arc<EdgeFaaS>) + Send + 'static>),
}

struct InstanceTask {
    run: RunId,
    app: String,
    function: String,
    /// Index into the node's placement list.
    instance: usize,
    resource: ResourceId,
    /// Fully-assembled invocation envelope, built once at fire time (the
    /// node-common head is serialized once and shared across placements).
    /// Shared `Bytes`: the batch protocol clones refcounts, not payloads.
    envelope: Bytes,
}

/// Bookkeeping for one in-flight workflow run.
struct RunEntry {
    app_name: String,
    app: Arc<Application>,
    entry_inputs: HashMap<String, Vec<String>>,
    state: RunState,
    /// Nodes already fired (guards duplicate entrypoints).
    fired: HashSet<String>,
    /// Node -> instances still executing.
    pending: HashMap<String, usize>,
    /// Node -> per-instance results collected so far.
    partial: HashMap<String, Vec<Option<InstanceResult>>>,
    result: WorkflowResult,
    /// Tasks enqueued but not yet finished (0 = run drained).
    open_tasks: usize,
    started: f64,
    failed: Option<String>,
    done: bool,
}

/// Queue + admission state, under a single lock so slot acquisition and
/// release cannot deadlock against the pop path.
struct QueueState {
    ready: VecDeque<Task>,
    /// Instances that were popped but found their resource at its admission
    /// limit; re-scanned whenever a slot frees up.
    deferred: VecDeque<InstanceTask>,
    /// Resource -> instances currently executing on it.
    in_use: HashMap<ResourceId, usize>,
    /// Live worker threads.
    workers: usize,
    /// Workers currently executing a task (the rest are polling or about to
    /// exit). `workers - busy` is the free capacity `ensure_workers`
    /// compares against the backlog, so a long-running task never blocks a
    /// short run from getting a fresh worker.
    busy: usize,
}

/// Table of workflow runs plus the retention queue of completed ones.
struct RunTable {
    map: HashMap<RunId, RunEntry>,
    /// Completed runs not yet consumed, oldest first. Bounded by
    /// [`MAX_FINISHED_RUNS`] so submit-and-forget clients (e.g. a crashed
    /// REST poller) cannot grow the coordinator's memory without bound.
    finished: VecDeque<RunId>,
}

/// Completed-but-unconsumed runs retained before the oldest are evicted.
pub const MAX_FINISHED_RUNS: usize = 1024;

type EventCallback = Arc<dyn Fn(&EdgeFaaS, &EngineEvent) + Send + Sync>;

/// The shared execution core owned by [`EdgeFaaS`].
pub(super) struct EngineCore {
    next_run: AtomicU64,
    max_workers: AtomicUsize,
    per_resource_slots: AtomicUsize,
    /// Largest per-resource invocation batch a worker may drain (1 =
    /// batching off: every instance dispatches individually).
    max_batch: AtomicUsize,
    queue: Mutex<QueueState>,
    queue_cv: Condvar,
    runs: Mutex<RunTable>,
    done_cv: Condvar,
    callbacks: Mutex<Vec<EventCallback>>,
}

/// Default cap on worker threads (lazily spawned, exit when idle).
pub const DEFAULT_MAX_WORKERS: usize = 16;
/// Default concurrently-executing instances admitted per resource.
pub const DEFAULT_PER_RESOURCE_SLOTS: usize = 8;
/// Default cap on a per-resource invocation batch (see the module docs).
pub const DEFAULT_MAX_BATCH: usize = 16;

impl EngineCore {
    pub(super) fn new() -> EngineCore {
        EngineCore {
            next_run: AtomicU64::new(0),
            max_workers: AtomicUsize::new(DEFAULT_MAX_WORKERS),
            per_resource_slots: AtomicUsize::new(DEFAULT_PER_RESOURCE_SLOTS),
            max_batch: AtomicUsize::new(DEFAULT_MAX_BATCH),
            queue: Mutex::new(QueueState {
                ready: VecDeque::new(),
                deferred: VecDeque::new(),
                in_use: HashMap::new(),
                workers: 0,
                busy: 0,
            }),
            queue_cv: Condvar::new(),
            runs: Mutex::new(RunTable { map: HashMap::new(), finished: VecDeque::new() }),
            done_cv: Condvar::new(),
            callbacks: Mutex::new(Vec::new()),
        }
    }

    fn enqueue(&self, tasks: Vec<Task>) {
        if tasks.is_empty() {
            return;
        }
        let mut q = self.queue.lock().unwrap();
        for t in tasks {
            q.ready.push_back(t);
        }
        drop(q);
        self.queue_cv.notify_all();
    }
}

enum Popped {
    Task(Task),
    /// Nothing queued at all: the worker may exit.
    Empty,
    /// Only admission-blocked instances remain: wait for a slot release.
    Blocked,
}

fn pop_task(q: &mut QueueState, limit: usize) -> Popped {
    // Deferred instances first: a slot may have freed since they blocked.
    for i in 0..q.deferred.len() {
        let rid = q.deferred[i].resource;
        if q.in_use.get(&rid).copied().unwrap_or(0) < limit {
            let t = q.deferred.remove(i).expect("index in bounds");
            *q.in_use.entry(rid).or_insert(0) += 1;
            return Popped::Task(Task::Instance(t));
        }
    }
    while let Some(task) = q.ready.pop_front() {
        match task {
            Task::Job(j) => return Popped::Task(Task::Job(j)),
            Task::Instance(t) => {
                let rid = t.resource;
                if q.in_use.get(&rid).copied().unwrap_or(0) < limit {
                    *q.in_use.entry(rid).or_insert(0) += 1;
                    return Popped::Task(Task::Instance(t));
                }
                q.deferred.push_back(t);
            }
        }
    }
    if q.deferred.is_empty() {
        Popped::Empty
    } else {
        Popped::Blocked
    }
}

/// Execute one placement instance: call the resource gateway with the
/// prebuilt envelope and parse the outputs (the invoker's wire format).
///
/// A panicking function handler is caught and converted into an instance
/// error: letting it unwind through the worker would leak the admission
/// slot and busy/worker counts and leave the run's `open_tasks` stuck above
/// zero — wedging a synchronous `run_workflow` caller forever.
fn run_instance(faas: &EdgeFaaS, t: &InstanceTask) -> anyhow::Result<InstanceResult> {
    let invoked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || -> anyhow::Result<InstanceResult> {
            let reg = faas.resource(t.resource)?;
            let qname = EdgeFaaS::qualified(&t.app, &t.function);
            let (out, latency) = reg.handle.invoke(&qname, &t.envelope)?;
            let outputs = parse_outputs(&out)?;
            Ok(InstanceResult { resource: t.resource, outputs, latency })
        },
    ));
    match invoked {
        Ok(result) => result,
        Err(payload) => {
            let what = crate::util::panic_message(&*payload);
            Err(anyhow::anyhow!("function handler panicked: {what}"))
        }
    }
}

/// Pull queued instances bound for `rid` (admission-deferred first — they
/// are oldest — then ready-queue order) into `out`, up to `max_total`
/// entries. The drained instances execute sequentially under the admission
/// slot the first instance already holds, so the per-resource concurrency
/// bound is preserved.
///
/// Ready-queue instances are drained only while the resource is saturated
/// (`in_use >= limit`): below the limit, an idle worker could run them in
/// parallel, and pulling them into this batch would trade that parallelism
/// away. Deferred instances are admission-blocked either way, so joining
/// the batch never costs them anything.
fn drain_same_resource(
    q: &mut QueueState,
    rid: ResourceId,
    limit: usize,
    max_total: usize,
    out: &mut Vec<InstanceTask>,
) {
    let mut i = 0;
    while out.len() < max_total && i < q.deferred.len() {
        if q.deferred[i].resource == rid {
            out.push(q.deferred.remove(i).expect("index in bounds"));
        } else {
            i += 1;
        }
    }
    if q.in_use.get(&rid).copied().unwrap_or(0) < limit {
        return;
    }
    let mut i = 0;
    while out.len() < max_total && i < q.ready.len() {
        let matches_rid = matches!(&q.ready[i], Task::Instance(t) if t.resource == rid);
        if matches_rid {
            match q.ready.remove(i) {
                Some(Task::Instance(t)) => out.push(t),
                _ => unreachable!("checked variant above"),
            }
        } else {
            i += 1;
        }
    }
}

fn engine_worker(faas: Arc<EdgeFaaS>) {
    loop {
        let task = {
            let mut q = faas.engine.queue.lock().unwrap();
            loop {
                let limit = faas.engine.per_resource_slots.load(Ordering::Relaxed).max(1);
                match pop_task(&mut q, limit) {
                    Popped::Task(t) => {
                        q.busy += 1;
                        break Some(t);
                    }
                    Popped::Empty => {
                        q.workers -= 1;
                        break None;
                    }
                    Popped::Blocked => q = faas.engine.queue_cv.wait(q).unwrap(),
                }
            }
        };
        let Some(task) = task else { return };
        match task {
            Task::Job(job) => {
                // Same containment as run_instance: a panicking job must
                // not kill the worker and leak the busy/worker counts.
                let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(&faas)));
                if ran.is_err() {
                    log::warn!("engine job panicked; worker kept alive");
                }
                let mut q = faas.engine.queue.lock().unwrap();
                q.busy = q.busy.saturating_sub(1);
            }
            Task::Instance(first) => {
                let rid = first.resource;
                // Opportunistically drain more same-resource work into one
                // batch (amortizes slot bookkeeping, completion locking and
                // — through the backend's Batch verb — the gateway round
                // trip). The batch runs sequentially on this worker under
                // the single slot acquired by the pop above.
                let mut tasks = vec![first];
                let max_batch = faas.engine.max_batch.load(Ordering::Relaxed).max(1);
                if max_batch > 1 {
                    let limit = faas.engine.per_resource_slots.load(Ordering::Relaxed).max(1);
                    let mut q = faas.engine.queue.lock().unwrap();
                    drain_same_resource(&mut q, rid, limit, max_batch, &mut tasks);
                }
                faas.run_batch(rid, tasks);
                {
                    let mut q = faas.engine.queue.lock().unwrap();
                    q.busy = q.busy.saturating_sub(1);
                    if let Some(n) = q.in_use.get_mut(&rid) {
                        *n = n.saturating_sub(1);
                        if *n == 0 {
                            q.in_use.remove(&rid);
                        }
                    }
                }
                faas.engine.queue_cv.notify_all();
            }
        }
    }
}

impl EdgeFaaS {
    /// Submit a workflow run to the engine; returns immediately with its
    /// [`RunId`]. Entry functions fire at once; dependents fire as their
    /// dependencies complete, interleaved with every other in-flight run.
    pub fn submit_workflow(
        self: &Arc<Self>,
        app: &str,
        entry_inputs: &HashMap<String, Vec<String>>,
    ) -> anyhow::Result<RunId> {
        let application = self.app(app)?;
        let run = self.engine.next_run.fetch_add(1, Ordering::SeqCst);
        let mut events = Vec::new();
        {
            let mut runs = self.engine.runs.lock().unwrap();
            let entry = RunEntry {
                app_name: app.to_string(),
                app: Arc::clone(&application),
                entry_inputs: entry_inputs.clone(),
                state: RunState::new(&application.dag),
                fired: HashSet::new(),
                pending: HashMap::new(),
                partial: HashMap::new(),
                result: WorkflowResult::default(),
                open_tasks: 0,
                started: self.clock.now(),
                failed: None,
                done: false,
            };
            // Insert before enqueueing so a fast worker finds the entry.
            runs.map.insert(run, entry);
            let completed = {
                let entry = runs.map.get_mut(&run).expect("just inserted");
                let entrypoints = application.config.entrypoints.clone();
                let mut batch = Vec::new();
                for f in &entrypoints {
                    if let Err(e) = self.fire_node(run, entry, f, &mut batch) {
                        entry.failed.get_or_insert(e.to_string());
                        break;
                    }
                }
                self.engine.enqueue(batch);
                self.check_done(run, entry, &mut events)
            };
            if completed {
                Self::retire_finished(&mut runs, run);
            }
        }
        self.emit_events(&events);
        self.ensure_workers();
        Ok(run)
    }

    /// Block until a run completes (or `timeout_s` elapses; pass
    /// `f64::INFINITY` to wait forever). Consumes the run's record.
    pub fn wait_workflow(&self, run: RunId, timeout_s: f64) -> anyhow::Result<WorkflowResult> {
        let deadline = if timeout_s.is_finite() {
            Some(
                std::time::Instant::now()
                    + std::time::Duration::from_secs_f64(timeout_s.max(0.0)),
            )
        } else {
            None
        };
        let mut runs = self.engine.runs.lock().unwrap();
        loop {
            let done = match runs.map.get(&run) {
                None => anyhow::bail!("unknown workflow run {run}"),
                Some(e) => e.done,
            };
            if done {
                let entry = runs.map.remove(&run).expect("checked above");
                return match entry.failed {
                    Some(msg) => Err(anyhow::anyhow!(msg)),
                    None => Ok(entry.result),
                };
            }
            match deadline {
                None => runs = self.engine.done_cv.wait(runs).unwrap(),
                Some(d) => {
                    let now = std::time::Instant::now();
                    if now >= d {
                        anyhow::bail!("workflow run {run} timed out");
                    }
                    let (g, _) = self.engine.done_cv.wait_timeout(runs, d - now).unwrap();
                    runs = g;
                }
            }
        }
    }

    /// Non-blocking peek at a run (None once consumed by `wait_workflow` /
    /// `take_run`).
    pub fn run_status(&self, run: RunId) -> Option<RunStatus> {
        let runs = self.engine.runs.lock().unwrap();
        runs.map.get(&run).map(|e| {
            if !e.done {
                RunStatus::Running
            } else if let Some(msg) = &e.failed {
                RunStatus::Failed(msg.clone())
            } else {
                RunStatus::Done(e.result.clone())
            }
        })
    }

    /// Like [`Self::run_status`], but removes the record once the run is
    /// done (the REST gateway's poll-then-forget semantics).
    pub fn take_run(&self, run: RunId) -> Option<RunStatus> {
        let mut runs = self.engine.runs.lock().unwrap();
        let done = runs.map.get(&run)?.done;
        if !done {
            return Some(RunStatus::Running);
        }
        let entry = runs.map.remove(&run).expect("checked above");
        Some(match entry.failed {
            Some(msg) => RunStatus::Failed(msg),
            None => RunStatus::Done(entry.result),
        })
    }

    /// Run an opaque job on the engine's worker pool (the async-invoke
    /// front-end; also usable for background coordinator chores).
    ///
    /// Jobs may themselves block on further engine progress (a nested
    /// `invoke_async`, a `run_workflow` issued from a background chore), so
    /// unlike instances they are never allowed to deadlock against the
    /// worker cap: when no free worker exists at submission time, one
    /// worker is spawned past `max_workers` — bounded by one thread per
    /// outstanding job, the same bound the old thread-per-async-invocation
    /// design had.
    pub fn spawn_job(self: &Arc<Self>, job: impl FnOnce(&Arc<EdgeFaaS>) + Send + 'static) {
        self.engine.enqueue(vec![Task::Job(Box::new(job))]);
        let overflow = {
            let mut q = self.engine.queue.lock().unwrap();
            if q.workers.saturating_sub(q.busy) == 0 {
                q.workers += 1;
                true
            } else {
                false
            }
        };
        if overflow {
            let faas = Arc::clone(self);
            let spawned = std::thread::Builder::new()
                .name("engine-worker".into())
                .spawn(move || engine_worker(faas));
            if spawned.is_err() {
                self.engine.queue.lock().unwrap().workers -= 1;
            }
        } else {
            self.ensure_workers();
        }
    }

    /// Subscribe to engine completion events. Callbacks run on worker
    /// threads after the engine's locks are released, so they may call back
    /// into the coordinator (e.g. `reschedule_function` on load changes).
    pub fn on_engine_event(&self, cb: impl Fn(&EdgeFaaS, &EngineEvent) + Send + Sync + 'static) {
        self.engine.callbacks.lock().unwrap().push(Arc::new(cb));
    }

    /// Tune the engine: worker-thread cap and per-resource admission slots
    /// (both clamped to >= 1). Takes effect for subsequent scheduling
    /// decisions.
    pub fn set_engine_limits(&self, max_workers: usize, per_resource_slots: usize) {
        self.engine.max_workers.store(max_workers.max(1), Ordering::Relaxed);
        self.engine.per_resource_slots.store(per_resource_slots.max(1), Ordering::Relaxed);
        self.engine.queue_cv.notify_all();
    }

    /// Toggle per-resource invocation batching (see the module docs).
    /// Enabled by default with [`DEFAULT_MAX_BATCH`]; disabling dispatches
    /// every instance individually. Batching on or off, runs produce
    /// identical firing orders and outputs — only the dispatch overhead
    /// changes.
    pub fn set_batching(&self, enabled: bool) {
        self.set_max_batch(if enabled { DEFAULT_MAX_BATCH } else { 1 });
    }

    /// Cap the per-resource invocation batch size (clamped to >= 1; 1
    /// disables batching).
    pub fn set_max_batch(&self, max_batch: usize) {
        self.engine.max_batch.store(max_batch.max(1), Ordering::Relaxed);
    }

    /// Whether per-resource invocation batching is currently enabled.
    pub fn batching_enabled(&self) -> bool {
        self.engine.max_batch.load(Ordering::Relaxed) > 1
    }

    // ------------------------------------------------------------ internal --

    /// Fire one DAG node: route its inputs, record bookkeeping, and collect
    /// one task per placement instance into `batch`.
    ///
    /// Envelopes are assembled here, once per instance, into shared
    /// [`Bytes`]: the node-common `{"app":...,"function":...` head is
    /// serialized exactly once and shared across placements, and workers
    /// never rebuild or re-serialize a JSON tree on the dispatch path. Key
    /// order (`app`, `function`, `inputs`, `resource`) matches the sorted
    /// order [`Json`] serialization used, so the wire format is unchanged.
    fn fire_node(
        &self,
        run: RunId,
        entry: &mut RunEntry,
        fname: &str,
        batch: &mut Vec<Task>,
    ) -> anyhow::Result<()> {
        if !entry.fired.insert(fname.to_string()) {
            return Ok(());
        }
        let app = entry.app_name.clone();
        let placements = self.candidates_of(&app, fname)?;
        if placements.is_empty() {
            anyhow::bail!("function `{app}.{fname}` has no placements");
        }
        let per_instance =
            self.route_inputs(&app, fname, &placements, &entry.entry_inputs, &entry.result)?;
        entry.result.firing_order.push(fname.to_string());
        entry.pending.insert(fname.to_string(), placements.len());
        entry.partial.insert(fname.to_string(), vec![None; placements.len()]);
        entry.open_tasks += placements.len();
        // Serialize the node-common envelope head once (JSON-escaped).
        let mut head = String::with_capacity(32 + app.len() + fname.len());
        head.push_str("{\"app\":");
        head.push_str(&Json::Str(app.clone()).to_string());
        head.push_str(",\"function\":");
        head.push_str(&Json::Str(fname.to_string()).to_string());
        for (i, (rid, inputs)) in placements.into_iter().zip(per_instance).enumerate() {
            let inputs_json = Json::Arr(inputs.into_iter().map(Json::Str).collect()).to_string();
            let mut env = String::with_capacity(head.len() + inputs_json.len() + 24);
            env.push_str(&head);
            env.push_str(",\"inputs\":");
            env.push_str(&inputs_json);
            env.push_str(",\"resource\":");
            env.push_str(&(rid as u64).to_string());
            env.push('}');
            batch.push(Task::Instance(InstanceTask {
                run,
                app: app.clone(),
                function: fname.to_string(),
                instance: i,
                resource: rid,
                envelope: Bytes::from(env),
            }));
        }
        Ok(())
    }

    /// Execute a drained same-resource batch and fan the results back out
    /// to their runs. A batch of one takes the exact single-instance path;
    /// larger batches go through the backend's `Batch` verb
    /// ([`super::handle::ResourceHandle::invoke_batch`]) — one gateway
    /// round trip, per-entry failure containment, results in task order.
    fn run_batch(self: &Arc<Self>, rid: ResourceId, tasks: Vec<InstanceTask>) {
        // Fast-drain instances of runs that already failed or finished
        // (one lock for the whole batch). Like the unbatched path — where
        // siblings already executing on other workers cannot be recalled
        // either — this check is best-effort: a run failing mid-batch
        // wastes at most the remainder of this one batch.
        let skip: Vec<bool> = {
            let runs = self.engine.runs.lock().unwrap();
            tasks
                .iter()
                .map(|t| {
                    runs.map.get(&t.run).map(|e| e.failed.is_some() || e.done).unwrap_or(true)
                })
                .collect()
        };
        let mut outcomes: Vec<Option<anyhow::Result<InstanceResult>>> =
            skip.iter().map(|_| None).collect();
        let live: Vec<usize> = (0..tasks.len()).filter(|&i| !skip[i]).collect();
        match live.len() {
            0 => {}
            1 => {
                let i = live[0];
                outcomes[i] = Some(run_instance(self, &tasks[i]));
            }
            _ => match self.resource(rid) {
                Err(e) => {
                    let msg = e.to_string();
                    for &i in &live {
                        outcomes[i] = Some(Err(anyhow::anyhow!(msg.clone())));
                    }
                }
                Ok(reg) => {
                    // Refcount bumps only: the envelopes were built at fire
                    // time and are shared with the backend call.
                    let calls: Vec<(String, Bytes)> = live
                        .iter()
                        .map(|&i| {
                            let t = &tasks[i];
                            (EdgeFaaS::qualified(&t.app, &t.function), t.envelope.clone())
                        })
                        .collect();
                    let invoked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        reg.handle.invoke_batch(&calls)
                    }));
                    match invoked {
                        Ok(results) => {
                            // Enforce the one-result-per-call contract: a
                            // misbehaving handle returning too few results
                            // must fail the unmatched tasks loudly, not
                            // strand them as "skipped" (which would wedge
                            // the run's pending count forever).
                            let mut results = results.into_iter();
                            for &i in &live {
                                outcomes[i] = Some(match results.next() {
                                    Some(result) => result.and_then(|(out, latency)| {
                                        Ok(InstanceResult {
                                            resource: rid,
                                            outputs: parse_outputs(&out)?,
                                            latency,
                                        })
                                    }),
                                    None => Err(anyhow::anyhow!(
                                        "backend returned too few batch results"
                                    )),
                                });
                            }
                        }
                        Err(payload) => {
                            // Only a handle without per-entry containment
                            // can unwind to here; fail the whole batch.
                            let what = crate::util::panic_message(&*payload);
                            for &i in &live {
                                outcomes[i] = Some(Err(anyhow::anyhow!(
                                    "function handler panicked: {what}"
                                )));
                            }
                        }
                    }
                }
            },
        }
        self.complete_batch(&tasks, outcomes);
    }

    /// Process a batch of finished (or skipped) instances, sequentially in
    /// task order — exactly the bookkeeping N single completions would do,
    /// but with the run-table lock taken twice per batch instead of twice
    /// per task.
    ///
    /// Two lock phases with the node-completion events emitted *between*
    /// them: subscribers observe `NodeCompleted` before the node's
    /// dependents are scheduled, so a callback (e.g. one invoking
    /// `reschedule_function` against fresh monitoring data) can still
    /// influence where the next stage lands.
    fn complete_batch(
        self: &Arc<Self>,
        tasks: &[InstanceTask],
        outcomes: Vec<Option<anyhow::Result<InstanceResult>>>,
    ) {
        // Phase 1: record every instance; detect node completions.
        let mut node_events = Vec::new();
        let mut node_done = vec![false; tasks.len()];
        {
            let mut runs = self.engine.runs.lock().unwrap();
            for ((idx, task), outcome) in tasks.iter().enumerate().zip(outcomes) {
                let Some(entry) = runs.map.get_mut(&task.run) else { continue };
                entry.open_tasks = entry.open_tasks.saturating_sub(1);
                match outcome {
                    None => {} // skipped: the run had already failed
                    Some(Ok(r)) => {
                        if entry.failed.is_none() {
                            if let Some(slots) = entry.partial.get_mut(&task.function) {
                                slots[task.instance] = Some(r);
                            }
                            node_done[idx] = match entry.pending.get_mut(&task.function) {
                                Some(p) => {
                                    *p -= 1;
                                    *p == 0
                                }
                                None => false,
                            };
                            if node_done[idx] {
                                entry.pending.remove(&task.function);
                                let slots =
                                    entry.partial.remove(&task.function).unwrap_or_default();
                                let instances: Vec<InstanceResult> =
                                    slots.into_iter().flatten().collect();
                                let latency =
                                    instances.iter().map(|i| i.latency).fold(0.0, f64::max);
                                node_events.push(EngineEvent::NodeCompleted {
                                    run: task.run,
                                    app: entry.app_name.clone(),
                                    function: task.function.clone(),
                                    instances: instances.len(),
                                    latency,
                                });
                                entry.result.functions.insert(task.function.clone(), instances);
                            }
                        }
                    }
                    Some(Err(e)) => {
                        let msg = format!(
                            "workflow `{}` function `{}` on resource {}: {e}",
                            entry.app_name, task.function, task.resource
                        );
                        log::warn!("{msg}");
                        entry.failed.get_or_insert(msg);
                        entry.pending.remove(&task.function);
                        entry.partial.remove(&task.function);
                    }
                }
            }
        }
        self.emit_events(&node_events);

        // Phase 2: fire newly-ready dependents (sorted by topological index
        // for deterministic firing orders) in task order so firing orders
        // match unbatched execution — for EVERY completed node in the batch
        // before any run-completion check. Two batch entries can belong to
        // one run, and `check_done` treats `open_tasks == 0` as
        // run-complete: checking an earlier entry's run before a later
        // entry fired its dependents would retire the run with downstream
        // nodes unfired. (The unbatched path kept this invariant implicitly
        // by interleaving fire and check per instance.)
        let mut run_events = Vec::new();
        {
            let mut runs = self.engine.runs.lock().unwrap();
            let mut to_enqueue = Vec::new();
            for (idx, task) in tasks.iter().enumerate() {
                if !node_done[idx] {
                    continue;
                }
                let Some(entry) = runs.map.get_mut(&task.run) else { continue };
                if entry.failed.is_some() {
                    continue;
                }
                let application = Arc::clone(&entry.app);
                let mut ready = entry.state.complete(&application.dag, &task.function);
                ready.sort_by_key(|n| {
                    application.dag.topo_order.iter().position(|x| x == n).unwrap_or(usize::MAX)
                });
                for f in &ready {
                    if let Err(e) = self.fire_node(task.run, entry, f, &mut to_enqueue) {
                        entry.failed.get_or_insert(e.to_string());
                        break;
                    }
                }
            }
            // Now detect run completions (idempotent per run via the `done`
            // flag, so duplicate runs in one batch check harmlessly twice).
            for task in tasks {
                let completed = match runs.map.get_mut(&task.run) {
                    None => false,
                    Some(entry) => self.check_done(task.run, entry, &mut run_events),
                };
                if completed {
                    Self::retire_finished(&mut runs, task.run);
                }
            }
            // One enqueue (queue lock + wakeup) for the whole batch.
            self.engine.enqueue(to_enqueue);
        }
        if run_events.iter().any(|e| matches!(e, EngineEvent::RunCompleted { .. })) {
            self.engine.done_cv.notify_all();
        }
        self.emit_events(&run_events);
        self.ensure_workers();
    }

    /// Mark a drained run done; returns true on the completing transition.
    fn check_done(&self, run: RunId, entry: &mut RunEntry, events: &mut Vec<EngineEvent>) -> bool {
        if !entry.done && entry.open_tasks == 0 {
            entry.done = true;
            entry.result.duration = self.clock.now() - entry.started;
            events.push(EngineEvent::RunCompleted {
                run,
                app: entry.app_name.clone(),
                ok: entry.failed.is_none(),
                duration: entry.result.duration,
            });
            return true;
        }
        false
    }

    /// Record a just-completed run in the retention queue, evicting the
    /// oldest completed-but-unconsumed runs beyond [`MAX_FINISHED_RUNS`].
    /// (Runs consumed by `wait_workflow`/`take_run` leave stale ids behind;
    /// those pop harmlessly here.)
    fn retire_finished(runs: &mut RunTable, run: RunId) {
        while runs.finished.len() >= MAX_FINISHED_RUNS {
            let Some(old) = runs.finished.pop_front() else { break };
            if runs.map.get(&old).map(|e| e.done).unwrap_or(false) {
                runs.map.remove(&old);
            }
        }
        runs.finished.push_back(run);
    }

    fn emit_events(&self, events: &[EngineEvent]) {
        if events.is_empty() {
            return;
        }
        let cbs: Vec<EventCallback> = self.engine.callbacks.lock().unwrap().clone();
        for ev in events {
            for cb in &cbs {
                cb(self, ev);
            }
        }
    }

    /// Spawn worker threads up to the cap, one per pending task. Workers
    /// exit when the queue drains, so an idle coordinator holds no threads.
    fn ensure_workers(self: &Arc<Self>) {
        loop {
            {
                let mut q = self.engine.queue.lock().unwrap();
                let limit = self.engine.per_resource_slots.load(Ordering::Relaxed).max(1);
                // Admission-blocked deferred instances are not runnable
                // demand — a thread spawned for them could only park on the
                // condvar until a slot frees (and an existing worker will
                // pick them up then).
                let admissible_deferred = q
                    .deferred
                    .iter()
                    .filter(|t| q.in_use.get(&t.resource).copied().unwrap_or(0) < limit)
                    .count();
                let pending = q.ready.len() + admissible_deferred;
                let max = self.engine.max_workers.load(Ordering::Relaxed).max(1);
                // Compare the backlog against *free* capacity: workers stuck
                // in a long task must not stop a short run from getting a
                // fresh thread (no head-of-line blocking across runs).
                let available = q.workers.saturating_sub(q.busy);
                if q.workers >= max || available >= pending {
                    return;
                }
                q.workers += 1;
            }
            let faas = Arc::clone(self);
            let spawned = std::thread::Builder::new()
                .name("engine-worker".into())
                .spawn(move || engine_worker(faas));
            if spawned.is_err() {
                self.engine.queue.lock().unwrap().workers -= 1;
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::functions::FunctionPackage;
    use crate::simnet::{RealClock, VirtualClock};
    use crate::testbed::{paper_testbed, TestBed};
    use std::sync::atomic::AtomicUsize;

    /// A two-stage chain app: `gen` on the first two Pis -> `sum` on an
    /// edge, with counting handlers that thread a run tag through object
    /// URLs so concurrent runs are distinguishable.
    fn chain_bed(clock: Arc<dyn crate::simnet::Clock>) -> TestBed {
        let b = paper_testbed(clock);
        let faas = Arc::clone(&b.faas);
        let yaml = "\
application: chain
entrypoint: gen
dag:
  - name: gen
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
  - name: sum
    dependencies: gen
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: 1
";
        let mut data = HashMap::new();
        data.insert("gen".to_string(), vec![b.iot[0], b.iot[1]]);
        faas.configure_application(yaml, &data).unwrap();
        faas.create_bucket("chain", "work", Some(b.edges[0])).unwrap();
        {
            let faas = Arc::clone(&faas);
            b.executor.register("img/gen", move |payload: &[u8]| {
                let v = crate::util::json::parse(std::str::from_utf8(payload)?)?;
                let rid = v.get("resource").unwrap().as_u64().unwrap();
                // Entry inputs carry the run tag (one URL-ish string).
                let tag = v
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .and_then(|a| a.first())
                    .and_then(Json::as_str)
                    .unwrap_or("r?")
                    .rsplit('/')
                    .next()
                    .unwrap_or("r?")
                    .to_string();
                let obj = format!("{tag}-gen-{rid}.bin");
                let url = faas.put_object("chain", "work", &obj, tag.as_bytes())?;
                let mut out = Json::obj();
                out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
                Ok(out.to_string().into_bytes())
            });
        }
        {
            let faas = Arc::clone(&faas);
            b.executor.register("img/sum", move |payload: &[u8]| {
                let v = crate::util::json::parse(std::str::from_utf8(payload)?)?;
                let inputs = v.get("inputs").and_then(Json::as_arr).unwrap_or(&[]).to_vec();
                let mut tags: Vec<String> = Vec::new();
                for u in &inputs {
                    let data = faas.get_object_url(u.as_str().unwrap())?;
                    tags.push(String::from_utf8_lossy(&data).to_string());
                }
                tags.sort();
                tags.dedup();
                anyhow::ensure!(tags.len() == 1, "inputs from mixed runs: {tags:?}");
                let obj = format!("{}-sum-n{}.bin", tags[0], inputs.len());
                let url = faas.put_object("chain", "work", &obj, tags[0].as_bytes())?;
                let mut out = Json::obj();
                out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
                Ok(out.to_string().into_bytes())
            });
        }
        faas.deploy_function("chain", "gen", &FunctionPackage { code: "img/gen".into() })
            .unwrap();
        faas.deploy_function("chain", "sum", &FunctionPackage { code: "img/sum".into() })
            .unwrap();
        b
    }

    fn entry_for(run_tag: &str) -> HashMap<String, Vec<String>> {
        // Two pseudo-URL entry inputs; routing sends one to each gen
        // instance (parsing requires app/bucket/rid/object shape).
        let mut m = HashMap::new();
        m.insert(
            "gen".to_string(),
            vec![format!("chain/work/0/{run_tag}"), format!("chain/work/1/{run_tag}")],
        );
        m
    }

    #[test]
    fn submit_then_wait_runs_the_dag() {
        let b = chain_bed(Arc::new(RealClock::new()));
        let run = b.faas.submit_workflow("chain", &entry_for("r0")).unwrap();
        let result = b.faas.wait_workflow(run, 10.0).unwrap();
        assert_eq!(result.firing_order, vec!["gen", "sum"]);
        assert_eq!(result.functions["gen"].len(), 2);
        assert_eq!(result.functions["sum"].len(), 1);
        assert!(result.functions["sum"][0].outputs[0].contains("r0-sum-n2"));
        // The record was consumed.
        assert!(b.faas.run_status(run).is_none());
        assert!(b.faas.wait_workflow(run, 0.1).is_err());
    }

    #[test]
    fn concurrent_runs_interleave_and_stay_isolated() {
        for clock in [
            Arc::new(RealClock::new()) as Arc<dyn crate::simnet::Clock>,
            Arc::new(VirtualClock::new()) as Arc<dyn crate::simnet::Clock>,
        ] {
            let b = chain_bed(clock);
            let runs: Vec<(String, RunId)> = (0..6)
                .map(|i| {
                    let tag = format!("r{i}");
                    let id = b.faas.submit_workflow("chain", &entry_for(&tag)).unwrap();
                    (tag, id)
                })
                .collect();
            for (tag, id) in runs {
                let result = b.faas.wait_workflow(id, 30.0).unwrap();
                let out = &result.functions["sum"][0].outputs[0];
                assert!(
                    out.contains(&format!("{tag}-sum-n2")),
                    "run {tag} got cross-contaminated: {out}"
                );
                assert_eq!(result.firing_order, vec!["gen", "sum"]);
            }
        }
    }

    #[test]
    fn batching_on_and_off_produce_identical_results() {
        for enabled in [false, true] {
            let b = chain_bed(Arc::new(RealClock::new()));
            b.faas.set_batching(enabled);
            assert_eq!(b.faas.batching_enabled(), enabled);
            // One admission slot per resource forces queuing, so the
            // batched pass actually forms multi-task batches.
            b.faas.set_engine_limits(8, 1);
            let runs: Vec<(String, RunId)> = (0..6)
                .map(|i| {
                    let tag = format!("r{i}");
                    let id = b.faas.submit_workflow("chain", &entry_for(&tag)).unwrap();
                    (tag, id)
                })
                .collect();
            for (tag, id) in runs {
                let result = b.faas.wait_workflow(id, 30.0).unwrap();
                assert_eq!(result.firing_order, vec!["gen", "sum"], "batching={enabled}");
                let out = &result.functions["sum"][0].outputs[0];
                assert!(
                    out.contains(&format!("{tag}-sum-n2")),
                    "batching={enabled}: run {tag} contaminated: {out}"
                );
            }
        }
    }

    #[test]
    fn per_resource_admission_limit_is_enforced() {
        let b = chain_bed(Arc::new(RealClock::new()));
        b.faas.set_engine_limits(16, 1);
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        {
            let (live, peak) = (Arc::clone(&live), Arc::clone(&peak));
            b.executor.register("img/busy", move |_: &[u8]| {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(20));
                live.fetch_sub(1, Ordering::SeqCst);
                Ok(br#"{"outputs":[]}"#.to_vec())
            });
        }
        // A single-function app pinned to one Pi.
        let yaml = "\
application: busy
entrypoint: f
dag:
  - name: f
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
";
        let mut data = HashMap::new();
        data.insert("f".to_string(), vec![b.iot[0]]);
        b.faas.configure_application(yaml, &data).unwrap();
        b.faas.deploy_function("busy", "f", &FunctionPackage { code: "img/busy".into() }).unwrap();
        let ids: Vec<RunId> = (0..5)
            .map(|_| b.faas.submit_workflow("busy", &HashMap::new()).unwrap())
            .collect();
        for id in ids {
            b.faas.wait_workflow(id, 30.0).unwrap();
        }
        assert_eq!(
            peak.load(Ordering::SeqCst),
            1,
            "admission limit of 1 must serialize instances on the resource"
        );
    }

    #[test]
    fn events_fire_and_allow_midrun_rescheduling() {
        let b = chain_bed(Arc::new(RealClock::new()));
        let nodes = Arc::new(Mutex::new(Vec::<String>::new()));
        let runs_done = Arc::new(AtomicUsize::new(0));
        // Mid-run reaction: when `gen` completes, migrate `sum` to the other
        // edge before it fires (the reschedule_function hook point).
        let target = b.edges[1];
        b.faas
            .resource(target)
            .unwrap()
            .handle
            .deploy("chain.sum", "img/sum", 128 << 20, 0, &[])
            .unwrap();
        {
            let nodes = Arc::clone(&nodes);
            let runs_done = Arc::clone(&runs_done);
            b.faas.on_engine_event(move |faas, ev| match ev {
                EngineEvent::NodeCompleted { function, .. } => {
                    nodes.lock().unwrap().push(function.clone());
                    if function == "gen" {
                        faas.set_candidates("chain", "sum", vec![target]).unwrap();
                    }
                }
                EngineEvent::RunCompleted { ok, .. } => {
                    assert!(ok);
                    runs_done.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
        let run = b.faas.submit_workflow("chain", &entry_for("ev")).unwrap();
        let result = b.faas.wait_workflow(run, 10.0).unwrap();
        assert_eq!(result.functions["sum"][0].resource, target, "sum moved mid-run");
        assert_eq!(*nodes.lock().unwrap(), vec!["gen", "sum"]);
        assert_eq!(runs_done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn failed_stage_surfaces_the_handler_error() {
        let b = chain_bed(Arc::new(RealClock::new()));
        b.executor.register("img/sum", |_: &[u8]| anyhow::bail!("sum exploded"));
        let bad = b.faas.submit_workflow("chain", &entry_for("bad")).unwrap();
        let err = b.faas.wait_workflow(bad, 10.0).unwrap_err().to_string();
        assert!(err.contains("sum exploded"), "{err}");
    }

    #[test]
    fn unknown_app_and_unknown_run_error() {
        let b = chain_bed(Arc::new(RealClock::new()));
        assert!(b.faas.submit_workflow("ghost", &HashMap::new()).is_err());
        assert!(b.faas.wait_workflow(999_999, 0.05).is_err());
        assert!(b.faas.run_status(999_999).is_none());
    }
}
