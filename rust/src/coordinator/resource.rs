//! Resource management (§3.1): registration, the resource mapping, and the
//! central [`EdgeFaaS`] state shared by every coordinator verb.
//!
//! "Each resource is registered through a YAML file containing the resource
//! capability and gateway... Each registered resource is assigned a unique
//! resource ID... Once it is unregistered, the resource ID is reused for
//! other resources." Mappings are backed up through [`crate::backup`] (the
//! paper uses S3 + DynamoDB) so a restarted coordinator resumes scheduling
//! without losing state.

use std::collections::{BTreeMap, BinaryHeap, HashMap};
use std::cmp::Reverse;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};

use crate::backup::DurableKv;
use crate::cluster::spec::ResourceSpec;
use crate::monitor::liveness::{self, LeaseState, LivenessConfig, ResourceLease, Transition};
use crate::monitor::snapshot::{LatencyMatrix, MonitorSnapshot, SnapshotPlane, UsageSample};
use crate::simnet::{Clock, NodeId, RealClock, Tier, Topology, TransferModel};
use crate::util::json::Json;
use crate::util::yaml;

use super::appconfig::AppConfig;
use super::dag::Dag;
use super::engine::{EngineCore, EngineEvent};
use super::functions::FunctionPackage;
use super::handle::ResourceHandle;
use super::scheduler::{LocalityScheduler, Schedule, SchedCache};

/// Unique id assigned at registration (reused after unregistration).
pub type ResourceId = u32;

/// A registered resource: capability + gateway handle + network position.
pub struct RegisteredResource {
    pub id: ResourceId,
    pub spec: ResourceSpec,
    /// Node in the network topology (locality decisions).
    pub net_node: NodeId,
    pub handle: Arc<dyn ResourceHandle>,
}

/// An application known to the coordinator.
pub struct Application {
    pub config: AppConfig,
    pub dag: Dag,
}

/// The EdgeFaaS coordinator state.
pub struct EdgeFaaS {
    pub(super) resources: RwLock<BTreeMap<ResourceId, Arc<RegisteredResource>>>,
    free_ids: Mutex<BinaryHeap<Reverse<ResourceId>>>,
    next_id: Mutex<ResourceId>,
    pub(super) topology: RwLock<Topology>,
    pub(super) kv: DurableKv,
    pub(super) apps: RwLock<HashMap<String, Arc<Application>>>,
    /// candidate_resource mapping: "app.function" -> resource ids
    /// ("with the application name plus the function name as the key").
    pub(super) candidates: RwLock<HashMap<String, Vec<ResourceId>>>,
    /// bucket map: EdgeFaaS bucket name ("app.bucket") -> resource id.
    pub(super) buckets: RwLock<HashMap<String, ResourceId>>,
    /// application -> original (user-visible) bucket names.
    pub(super) app_buckets: RwLock<HashMap<String, Vec<String>>>,
    pub(super) scheduler: RwLock<Arc<dyn Schedule>>,
    pub(super) transfer: TransferModel,
    pub(super) clock: Arc<dyn Clock>,
    /// The event-driven execution core every invocation front-end submits
    /// through (see [`super::engine`]).
    pub(super) engine: EngineCore,
    /// The monitoring snapshot plane: epoch-versioned usage + latency view
    /// the scheduling fast path reads instead of scraping per decision
    /// (see [`crate::monitor::snapshot`]).
    pub(super) monitor: SnapshotPlane,
    /// Placement decision cache keyed by (app, function, anchor sets) and
    /// the snapshot epoch; invalidated on epoch bumps, resource
    /// (de)registration, app reconfiguration and scheduler swaps, bypassed
    /// by `reschedule_function` (see [`super::scheduler`]).
    pub(super) sched_cache: Mutex<SchedCache>,
    /// Deployment package last used per qualified function name — what the
    /// auto-reschedule policy redeploys with (recorded by
    /// `deploy_function`).
    pub(super) packages: RwLock<HashMap<String, FunctionPackage>>,
    /// Data anchors per qualified function name (the `data_locations` the
    /// function was configured with), so rescheduling can re-anchor
    /// data-affinity placements.
    pub(super) data_anchors: RwLock<HashMap<String, Vec<ResourceId>>>,
    /// Failure-detector configuration (dead-after / quarantine sweeps; see
    /// [`crate::monitor::liveness`]).
    liveness_cfg: Mutex<LivenessConfig>,
    /// Serializes collector sweeps: lease stepping is a read-modify-write
    /// over the previous snapshot's lease table, so two concurrent
    /// refreshes could double-count a miss or lose a `Died` transition.
    sweep_lock: Mutex<()>,
    /// Candidate memberships stripped from a resource when it was marked
    /// dead (qualified function names), kept so quarantine re-admission can
    /// restore them.
    dead_memberships: Mutex<HashMap<ResourceId, Vec<String>>>,
    /// This coordinator's membership in a multi-coordinator fleet, when
    /// federation is enabled (see [`super::federation::Federation::enable`]).
    pub(super) federation: RwLock<Option<Arc<super::federation::Federation>>>,
}

impl EdgeFaaS {
    /// A coordinator with an ephemeral backup store and real clock.
    pub fn new(topology: Topology) -> EdgeFaaS {
        Self::with_parts(topology, DurableKv::ephemeral(), Arc::new(RealClock::new()))
    }

    /// Full constructor.
    pub fn with_parts(topology: Topology, kv: DurableKv, clock: Arc<dyn Clock>) -> EdgeFaaS {
        // The dense latency matrix is lifted from the topology once here:
        // the topology graph is fixed after construction (registration only
        // *positions* resources on existing nodes), so every snapshot epoch
        // shares one matrix Arc.
        let latency = Arc::new(LatencyMatrix::from_topology(&topology));
        EdgeFaaS {
            resources: RwLock::new(BTreeMap::new()),
            free_ids: Mutex::new(BinaryHeap::new()),
            next_id: Mutex::new(0),
            topology: RwLock::new(topology),
            kv,
            apps: RwLock::new(HashMap::new()),
            candidates: RwLock::new(HashMap::new()),
            buckets: RwLock::new(HashMap::new()),
            app_buckets: RwLock::new(HashMap::new()),
            scheduler: RwLock::new(Arc::new(LocalityScheduler)),
            transfer: TransferModel::default(),
            clock,
            engine: EngineCore::new(),
            monitor: SnapshotPlane::new(latency),
            sched_cache: Mutex::new(SchedCache::default()),
            packages: RwLock::new(HashMap::new()),
            data_anchors: RwLock::new(HashMap::new()),
            liveness_cfg: Mutex::new(LivenessConfig::default()),
            sweep_lock: Mutex::new(()),
            dead_memberships: Mutex::new(HashMap::new()),
            federation: RwLock::new(None),
        }
    }

    /// This coordinator's federation membership, if enabled.
    pub fn federation(&self) -> Option<Arc<super::federation::Federation>> {
        self.federation.read().unwrap().clone()
    }

    /// Swap in a user scheduling policy ("EdgeFaaS also offers easy to use
    /// interface for users to implement their own scheduling policies").
    /// Invalidates the placement decision cache — cached decisions of the
    /// old policy must not masquerade as the new one's.
    pub fn set_scheduler(&self, s: Arc<dyn Schedule>) {
        *self.scheduler.write().unwrap() = s;
        self.invalidate_schedule_cache();
    }

    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    pub fn transfer_model(&self) -> &TransferModel {
        &self.transfer
    }

    // ------------------------------------------------------ registration --

    /// Register a resource from its Table-1 YAML plus a gateway handle and a
    /// position in the network topology. Returns the assigned resource ID.
    pub fn register_yaml(
        &self,
        yaml_text: &str,
        handle: Arc<dyn ResourceHandle>,
        net_node: NodeId,
    ) -> anyhow::Result<ResourceId> {
        let spec = ResourceSpec::from_yaml(&yaml::parse(yaml_text)?)?;
        self.register(spec, handle, net_node)
    }

    /// Register a resource from a parsed spec.
    pub fn register(
        &self,
        spec: ResourceSpec,
        handle: Arc<dyn ResourceHandle>,
        net_node: NodeId,
    ) -> anyhow::Result<ResourceId> {
        {
            let topo = self.topology.read().unwrap();
            if net_node >= topo.len() {
                anyhow::bail!("net node {net_node} not in topology");
            }
            if topo.node(net_node).tier != spec.tier {
                anyhow::bail!(
                    "tier mismatch: spec says {}, topology node is {}",
                    spec.tier.name(),
                    topo.node(net_node).tier.name()
                );
            }
        }
        let id = {
            let mut free = self.free_ids.lock().unwrap();
            match free.pop() {
                Some(Reverse(id)) => id,
                None => {
                    let mut next = self.next_id.lock().unwrap();
                    let id = *next;
                    *next += 1;
                    id
                }
            }
        };
        let mut rec = Json::obj();
        rec.set("tier", spec.tier.name().into())
            .set("gateway", spec.gateway.as_str().into())
            .set("net_node", net_node.into())
            .set("nodes", (spec.nodes as u64).into());
        self.kv.put("resource_map", &id.to_string(), rec)?;
        let reg = Arc::new(RegisteredResource { id, spec, net_node, handle });
        self.resources.write().unwrap().insert(id, reg);
        // A new resource can change any placement decision: drop the cache.
        self.invalidate_schedule_cache();
        self.publish_fleet_census();
        log::info!("registered resource {id} ({})", self.describe_resource(id));
        Ok(id)
    }

    fn describe_resource(&self, id: ResourceId) -> String {
        self.resources
            .read()
            .unwrap()
            .get(&id)
            .map(|r| format!("{} gw={}", r.spec.tier.name(), r.spec.gateway))
            .unwrap_or_else(|| "?".into())
    }

    /// Unregister a resource. Fails while functions are deployed or data is
    /// stored on it ("The user has to delete all the functions deployed on
    /// the resource and remove all the data stored in the resource").
    pub fn unregister(&self, id: ResourceId) -> anyhow::Result<()> {
        let reg = self
            .resources
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no resource {id}"))?;
        let deployed = reg.handle.list()?;
        if !deployed.is_empty() {
            anyhow::bail!("resource {id} still has functions deployed: {deployed:?}");
        }
        let stored = reg.handle.stored_bytes()?;
        if stored > 0 {
            anyhow::bail!("resource {id} still stores {stored} bytes");
        }
        // A resource with queued or in-flight engine work still owes runs
        // their completion events; yanking it would strand them with no
        // completion path. Refuse with a typed error naming the live runs —
        // the caller can wait them out (or kill the resource and let the
        // liveness plane drain it).
        let (runs, queued, in_flight) = self.live_instances_on(id);
        if queued > 0 || in_flight > 0 {
            return Err(anyhow::Error::new(super::engine::ResourceBusy {
                resource: id,
                runs,
                queued,
                in_flight,
            }));
        }
        self.resources.write().unwrap().remove(&id);
        self.kv.delete("resource_map", &id.to_string())?;
        self.free_ids.lock().unwrap().push(Reverse(id));
        // Forget any pending quarantine restore: the id may be reused by an
        // unrelated resource.
        self.dead_memberships.lock().unwrap().remove(&id);
        // Cached decisions may name the departed resource: drop the cache.
        self.invalidate_schedule_cache();
        self.publish_fleet_census();
        log::info!("unregistered resource {id}");
        Ok(())
    }

    pub fn resource(&self, id: ResourceId) -> anyhow::Result<Arc<RegisteredResource>> {
        self.resources
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no resource {id}"))
    }

    /// Snapshot of registered resource ids (sorted).
    pub fn resource_ids(&self) -> Vec<ResourceId> {
        self.resources.read().unwrap().keys().copied().collect()
    }

    /// Resources of a tier.
    pub fn tier_resources(&self, tier: Tier) -> Vec<ResourceId> {
        self.resources
            .read()
            .unwrap()
            .values()
            .filter(|r| r.spec.tier == tier)
            .map(|r| r.id)
            .collect()
    }

    /// One-way network latency between two registered resources.
    pub fn latency(&self, a: ResourceId, b: ResourceId) -> anyhow::Result<f64> {
        let (na, nb) = {
            let res = self.resources.read().unwrap();
            let ra = res.get(&a).ok_or_else(|| anyhow::anyhow!("no resource {a}"))?;
            let rb = res.get(&b).ok_or_else(|| anyhow::anyhow!("no resource {b}"))?;
            (ra.net_node, rb.net_node)
        };
        Ok(self.topology.read().unwrap().latency(na, nb))
    }

    /// Modeled transfer time for `bytes` between two resources.
    pub fn transfer_time(&self, from: ResourceId, to: ResourceId, bytes: u64) -> anyhow::Result<f64> {
        let (nf, nt) = {
            let res = self.resources.read().unwrap();
            let rf = res.get(&from).ok_or_else(|| anyhow::anyhow!("no resource {from}"))?;
            let rt = res.get(&to).ok_or_else(|| anyhow::anyhow!("no resource {to}"))?;
            (rf.net_node, rt.net_node)
        };
        Ok(self.transfer.time(&self.topology.read().unwrap(), nf, nt, bytes))
    }

    // ------------------------------------------------- monitoring plane --

    /// The current monitoring snapshot (a refcount bump; see
    /// [`crate::monitor::snapshot`]).
    pub fn monitor_snapshot(&self) -> Arc<MonitorSnapshot> {
        self.monitor.snapshot()
    }

    /// The snapshot plane's current epoch (0 until the first refresh).
    pub fn snapshot_epoch(&self) -> u64 {
        self.monitor.epoch()
    }

    /// The snapshot staleness bound, seconds: phase-1 reads a snapshot
    /// sample only while it is younger than this, falling back to a direct
    /// scrape of that resource otherwise.
    pub fn snapshot_max_age(&self) -> f64 {
        self.monitor.max_age()
    }

    /// Set the snapshot staleness bound (seconds, clamped to >= 0).
    pub fn set_snapshot_max_age(&self, max_age_s: f64) {
        self.monitor.set_max_age(max_age_s);
    }

    /// Whether a background monitor collector is currently running.
    pub fn monitor_collector_running(&self) -> bool {
        self.monitor.collector_running()
    }

    /// The failure detector's configuration (see
    /// [`crate::monitor::liveness`] for the lease lifecycle).
    pub fn liveness_config(&self) -> LivenessConfig {
        *self.liveness_cfg.lock().unwrap()
    }

    /// Tune the failure detector: consecutive missed sweeps before a
    /// resource is marked Dead, and consecutive clean sweeps a recovering
    /// resource must answer before re-admission (both clamped to >= 1).
    pub fn set_liveness(&self, dead_after: u32, quarantine_sweeps: u32) {
        *self.liveness_cfg.lock().unwrap() = LivenessConfig {
            dead_after: dead_after.max(1),
            quarantine_sweeps: quarantine_sweeps.max(1),
        };
    }

    /// Synchronously scrape every registered resource and publish a new
    /// snapshot epoch. Scrapes run outside the resource-map lock. Each
    /// sweep doubles as a heartbeat for the liveness plane: a resource
    /// whose scrape fails keeps its previous sample — visibly, with
    /// `consecutive_failures`/`last_error` carried on it — while its lease
    /// advances `Alive -> Suspect -> Dead` (and back through quarantine;
    /// see [`crate::monitor::liveness`]). A `Died` transition drains the
    /// resource's queued/in-flight work and strips its candidate
    /// memberships; a `Readmitted` one restores them. Departed resources
    /// are dropped. Returns the new epoch. This is the collector's refresh
    /// step, also callable directly (virtual-time tests, benches, or a
    /// scrape-now REST hook).
    pub fn refresh_monitor_snapshot(self: &Arc<Self>) -> u64 {
        self.refresh_monitor_snapshot_scoped(None)
    }

    /// [`Self::refresh_monitor_snapshot`] restricted to a slice of the
    /// fleet: scrape and lease-step only the `owned` resources, carrying
    /// every other registered resource's sample and lease forward
    /// untouched. This is a federated coordinator's sweep — it heartbeats
    /// the resources it owns, while peers' slices are refreshed by gossip
    /// merges from their owners ([`Self::merge_federated_view`]) instead
    /// of duplicate scrapes. `None` sweeps everything.
    pub(super) fn refresh_monitor_snapshot_scoped(
        self: &Arc<Self>,
        owned: Option<&std::collections::BTreeSet<ResourceId>>,
    ) -> u64 {
        // One sweep at a time: lease stepping is a read-modify-write of the
        // previous snapshot's lease table, and each Died/Readmitted
        // transition must fire its side effects exactly once.
        let _sweep = self.sweep_lock.lock().unwrap();
        let cfg = self.liveness_config();
        let targets: Vec<(ResourceId, Arc<dyn ResourceHandle>)> = {
            let res = self.resources.read().unwrap();
            res.values()
                .filter(|r| owned.map(|o| o.contains(&r.id)).unwrap_or(true))
                .map(|r| (r.id, Arc::clone(&r.handle)))
                .collect()
        };
        let prev = self.monitor.snapshot();
        let mut usage = BTreeMap::new();
        let mut leases = BTreeMap::new();
        if let Some(owned) = owned {
            // Carry non-owned (but still registered) entries forward
            // verbatim; departed resources drop out here exactly as they
            // do from a full sweep.
            let res = self.resources.read().unwrap();
            for (rid, sample) in prev.samples() {
                if !owned.contains(&rid) && res.contains_key(&rid) {
                    usage.insert(rid, sample.clone());
                }
            }
            for (rid, lease) in prev.leases() {
                if !owned.contains(&rid) && res.contains_key(&rid) {
                    leases.insert(rid, lease.clone());
                }
            }
        }
        let mut died = Vec::new();
        let mut readmitted = Vec::new();
        for (id, handle) in targets {
            let now = self.clock.now();
            let ok = match handle.usage() {
                Ok(u) => {
                    usage.insert(id, UsageSample::fresh(u, now));
                    true
                }
                Err(e) => {
                    log::warn!("monitor refresh: scrape of resource {id} failed: {e}");
                    // Carry the last-good reading, but visibly: the sample
                    // keeps its original collection time and counts the
                    // misses instead of masquerading as fresh forever.
                    if let Some(old) = prev.usage_of(id) {
                        usage.insert(
                            id,
                            UsageSample {
                                usage: old.usage,
                                collected_at: old.collected_at,
                                consecutive_failures: old.consecutive_failures + 1,
                                last_error: Some(e.to_string()),
                            },
                        );
                    }
                    false
                }
            };
            let (lease, transition) = liveness::step(&cfg, prev.lease_of(id), ok, now);
            match transition {
                Some(Transition::Died) => died.push(id),
                Some(Transition::Readmitted) => readmitted.push(id),
                None => {}
            }
            leases.insert(id, lease);
        }
        let now = self.clock.now();
        let epoch = self.monitor.publish(usage, leases, prev.latencies_arc(), now);
        self.publish_fleet_census();
        // Transition side effects run after the publish so drain and
        // relocation decisions read the epoch that declared the new state.
        for id in died {
            self.on_resource_dead(id);
        }
        for id in readmitted {
            self.on_resource_recovered(id);
        }
        epoch
    }

    /// Data-path liveness evidence: a connectivity-class failure (connect
    /// refused/timed out, request deadline, reset, truncation — never an
    /// application error) on live traffic to `id`. Steps that one
    /// resource's lease exactly as a missed detector sweep would — under
    /// the same sweep lock, with every other resource's lease and usage
    /// sample carried forward — and publishes a new snapshot epoch. A
    /// partitioned resource thus turns Suspect (and, after
    /// `dead_after` misses, Dead) from the traffic that hit the partition,
    /// between sweeps, instead of waiting for the detector's next pass.
    /// `ok = false` can never readmit, so at worst this accelerates what
    /// the next sweep would conclude; a recovered resource still
    /// re-admits through the sweep-driven quarantine path.
    pub fn report_data_path_miss(self: &Arc<Self>, id: ResourceId) {
        let _sweep = self.sweep_lock.lock().unwrap();
        // Departed resources carry no lease; nothing to report.
        if !self.resources.read().unwrap().contains_key(&id) {
            return;
        }
        let cfg = self.liveness_config();
        let prev = self.monitor.snapshot();
        let now = self.clock.now();
        let (lease, transition) = liveness::step(&cfg, prev.lease_of(id), false, now);
        let mut usage = BTreeMap::new();
        let mut leases = BTreeMap::new();
        for (rid, sample) in prev.samples() {
            usage.insert(rid, sample.clone());
        }
        for (rid, l) in prev.leases() {
            leases.insert(rid, l.clone());
        }
        if let Some(sample) = usage.get_mut(&id) {
            // The miss is visible on the sample too, like a failed scrape.
            sample.consecutive_failures += 1;
            sample.last_error = Some("data-path connectivity failure".to_string());
        }
        let died = matches!(transition, Some(Transition::Died));
        leases.insert(id, lease);
        self.monitor.publish(usage, leases, prev.latencies_arc(), now);
        self.publish_fleet_census();
        if died {
            self.on_resource_dead(id);
        }
    }

    /// Merge a peer coordinator's gossiped view into the local snapshot
    /// plane (see [`super::federation`] for the wire format and the push
    /// loop). `authoritative` names the resources the *sender owns* — its
    /// detector is the fleet-wide source of truth for them. Runs under the
    /// sweep lock: a merge is a read-modify-write of the lease table,
    /// exactly like a sweep. Merge rules:
    ///
    /// * **Usage** — a peer's sample replaces the local one iff it was
    ///   collected later (or the resource has no local sample), so phase-1
    ///   can place onto a peer's slice with zero remote scrapes while the
    ///   staleness bound still applies unchanged.
    /// * **Leases, owner-authoritative** — for `authoritative` resources
    ///   the peer's lease is adopted verbatim. Adopting schedulable→`Dead`
    ///   drains and relocates exactly like a local `Died` transition;
    ///   adopting unschedulable→schedulable re-admits. Only this path can
    ///   mark a resource `Dead` fleet-wide.
    /// * **Leases, pessimistic cap** — a non-owner's worse opinion can at
    ///   most raise a locally-`Alive` resource to `Suspect` (merged views
    ///   take the pessimistic state, but hearsay never drains); existing
    ///   local non-`Alive` evidence is kept as-is.
    ///
    /// Publishes a new epoch and returns it. When no lease *state* changed,
    /// the placement decision cache is re-keyed to the new epoch instead of
    /// invalidated — cached decisions stay valid across usage-only merges.
    pub(super) fn merge_federated_view(
        self: &Arc<Self>,
        authoritative: &std::collections::BTreeSet<ResourceId>,
        peer_usage: &BTreeMap<ResourceId, UsageSample>,
        peer_leases: &BTreeMap<ResourceId, ResourceLease>,
    ) -> u64 {
        let _sweep = self.sweep_lock.lock().unwrap();
        let prev = self.monitor.snapshot();
        let (mut usage, mut leases) = prev.clone_tables();
        let registered: std::collections::BTreeSet<ResourceId> =
            self.resource_ids().into_iter().collect();
        for (rid, sample) in peer_usage {
            if !registered.contains(rid) {
                continue;
            }
            let newer = usage
                .get(rid)
                .map(|local| sample.collected_at > local.collected_at)
                .unwrap_or(true);
            if newer {
                usage.insert(*rid, sample.clone());
            }
        }
        let mut died = Vec::new();
        let mut readmitted = Vec::new();
        let mut lease_changed = false;
        for (rid, peer) in peer_leases {
            if !registered.contains(rid) {
                continue;
            }
            let local_state = leases.get(rid).map(|l| l.state);
            if authoritative.contains(rid) {
                // A missing local lease means the detector has no opinion
                // yet — treated as schedulable everywhere else, so an
                // adopted Dead must still drain.
                let was_schedulable = local_state.map(|s| s.schedulable()).unwrap_or(true);
                if local_state != Some(peer.state) {
                    lease_changed = true;
                }
                if was_schedulable && peer.state == LeaseState::Dead {
                    died.push(*rid);
                }
                if local_state.is_some() && !was_schedulable && peer.state.schedulable() {
                    readmitted.push(*rid);
                }
                leases.insert(*rid, peer.clone());
            } else if local_state.unwrap_or(LeaseState::Alive) == LeaseState::Alive
                && peer.state.severity() > LeaseState::Alive.severity()
            {
                let cfg = self.liveness_config();
                let now = self.clock.now();
                // Cap the inherited miss count below dead_after: local
                // misses may still escalate, but the cap alone never kills.
                let max_misses = cfg.dead_after.max(1).saturating_sub(1).max(1);
                leases.insert(
                    *rid,
                    ResourceLease {
                        state: LeaseState::Suspect,
                        misses: peer.misses.clamp(1, max_misses),
                        clean_sweeps: 0,
                        since: now,
                        last_seen: leases.get(rid).and_then(|l| l.last_seen),
                    },
                );
                lease_changed = true;
            }
        }
        let now = self.clock.now();
        let epoch = self.monitor.publish(usage, leases, prev.latencies_arc(), now);
        if lease_changed {
            self.invalidate_schedule_cache();
        } else {
            self.sched_cache.lock().unwrap().rekey(epoch);
        }
        self.publish_fleet_census();
        // Side effects after the publish, like a sweep's: drains and
        // relocations read the epoch that declared the new state.
        for id in died {
            self.on_resource_dead(id);
        }
        for id in readmitted {
            self.on_resource_recovered(id);
        }
        epoch
    }

    /// Recompute the engine's fleet census — registered resources vs the
    /// subset whose lease is schedulable — feeding lease-aware admission
    /// ([`super::engine`]'s pending-run bound scales with the schedulable
    /// fraction). Resources the detector has not seen yet count as
    /// schedulable.
    fn publish_fleet_census(&self) {
        let snap = self.monitor.snapshot();
        let res = self.resources.read().unwrap();
        let total = res.len();
        let schedulable = res
            .keys()
            .filter(|id| snap.lease_of(**id).map(|l| l.state.schedulable()).unwrap_or(true))
            .count();
        drop(res);
        self.engine.set_fleet(total, schedulable);
    }

    /// Lease transition hook: `id` was just declared Dead by the detector.
    /// Strips it from every candidate mapping (recording the memberships
    /// for re-admission), drains its dispatch shard through the engine —
    /// queued instances move to surviving candidates or fail typed — emits
    /// [`EngineEvent::ResourceDead`], and relocates the functions it
    /// anchored via the make-before-break reschedule path.
    fn on_resource_dead(self: &Arc<Self>, id: ResourceId) {
        let mut stripped: Vec<String> = Vec::new();
        {
            let mut map = self.candidates.write().unwrap();
            for (qname, ids) in map.iter_mut() {
                if ids.contains(&id) {
                    ids.retain(|&x| x != id);
                    let rec = Json::Arr(ids.iter().map(|&i| Json::Num(i as f64)).collect());
                    let _ = self.kv.put("candidate_resource", qname, rec);
                    stripped.push(qname.clone());
                }
            }
        }
        if !stripped.is_empty() {
            self.dead_memberships.lock().unwrap().insert(id, stripped.clone());
        }
        self.invalidate_schedule_cache();
        let (queued_moved, queued_failed) = self.drain_dead_resource(id);
        log::warn!(
            "resource {id} marked dead: {queued_moved} queued instance(s) moved, \
             {queued_failed} failed"
        );
        self.emit_events(&[EngineEvent::ResourceDead {
            resource: id,
            queued_moved,
            queued_failed,
        }]);
        // Relocate what the dead resource anchored: every function whose
        // candidate set it belonged to is rescheduled make-before-break
        // against the post-death snapshot (the phase-1 filter now excludes
        // it). Failures are logged, not fatal — the drain above already
        // guaranteed every affected run a completion path.
        for qname in stripped {
            let Some((app, function)) = qname.split_once('.') else { continue };
            let package = self.packages.read().unwrap().get(&qname).cloned();
            let Some(package) = package else { continue };
            let anchors =
                self.data_anchors.read().unwrap().get(&qname).cloned().unwrap_or_default();
            if let Err(e) = self.reschedule_function(app, function, &package, anchors) {
                log::warn!("relocation of `{qname}` off dead resource {id} failed: {e}");
            }
        }
    }

    /// Lease transition hook: `id` survived quarantine and is re-admitted.
    /// Restores its recorded candidate memberships — best-effort
    /// redeploying each function's package first so a restored membership
    /// is actually servable — and emits [`EngineEvent::ResourceRecovered`].
    fn on_resource_recovered(self: &Arc<Self>, id: ResourceId) {
        let memberships = self.dead_memberships.lock().unwrap().remove(&id).unwrap_or_default();
        let Ok(reg) = self.resource(id) else { return };
        let deployed = reg.handle.list().unwrap_or_default();
        let mut restored = 0usize;
        for qname in &memberships {
            let Some((app, function)) = qname.split_once('.') else { continue };
            if !deployed.contains(qname) {
                // The resource may have rebooted and lost its sandboxes:
                // redeploy the recorded package before re-advertising.
                let package = self.packages.read().unwrap().get(qname).cloned();
                let Some(package) = package else { continue };
                let memory = super::asyncinvoke::request_memory(self, app, function)
                    .unwrap_or(128 << 20);
                let labels = vec![
                    ("app".to_string(), app.to_string()),
                    ("fn".to_string(), function.to_string()),
                ];
                if let Err(e) =
                    reg.handle.deploy(qname, &package.code, memory, 0, &labels)
                {
                    log::warn!("re-admission redeploy of `{qname}` on {id} failed: {e}");
                    continue;
                }
            }
            let mut map = self.candidates.write().unwrap();
            if let Some(ids) = map.get_mut(qname) {
                if !ids.contains(&id) {
                    ids.push(id);
                    let rec = Json::Arr(ids.iter().map(|&i| Json::Num(i as f64)).collect());
                    let _ = self.kv.put("candidate_resource", qname, rec);
                    restored += 1;
                }
            }
        }
        self.invalidate_schedule_cache();
        log::info!(
            "resource {id} re-admitted after quarantine ({restored} candidate membership(s) \
             restored)"
        );
        self.emit_events(&[EngineEvent::ResourceRecovered { resource: id }]);
    }

    /// Start the background monitor collector: a thread that refreshes the
    /// snapshot ([`Self::refresh_monitor_snapshot`]) then `Clock::sleep`s
    /// `interval_s`, until stopped — clock-generic, so under a
    /// `VirtualClock` the same loop advances virtual time instead of
    /// blocking. Returns `false` (without starting a second collector) if
    /// one is already running. The thread holds only a `Weak` reference to
    /// the coordinator, so dropping the last `Arc<EdgeFaaS>` also ends the
    /// collector.
    pub fn start_monitor_collector(self: &Arc<Self>, interval_s: f64) -> bool {
        let stop = Arc::new(AtomicBool::new(false));
        if !self.monitor.register_collector(Arc::clone(&stop)) {
            return false;
        }
        let weak: Weak<EdgeFaaS> = Arc::downgrade(self);
        let clock = Arc::clone(&self.clock);
        let interval = interval_s.max(0.0);
        let spawned = std::thread::Builder::new()
            .name("monitor-collector".into())
            .spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    let Some(faas) = weak.upgrade() else { break };
                    faas.refresh_monitor_snapshot();
                    drop(faas);
                    clock.sleep(interval);
                }
            });
        if spawned.is_err() {
            self.monitor.stop_collector();
            return false;
        }
        true
    }

    /// Signal the collector to stop after its current cycle (non-blocking;
    /// under a `RealClock` the thread exits within one interval).
    pub fn stop_monitor_collector(&self) {
        self.monitor.stop_collector();
    }

    /// Enable/disable the placement decision cache (enabled by default).
    /// Disabling also drops all cached decisions. Even when enabled, the
    /// cache only engages while decisions are snapshot-backed — the
    /// current snapshot is non-initial (epoch > 0) and within the
    /// staleness bound; otherwise every call pays the full scraping path.
    pub fn set_schedule_cache(&self, enabled: bool) {
        let mut cache = self.sched_cache.lock().unwrap();
        cache.enabled = enabled;
        cache.map.clear();
    }

    /// Decision-cache statistics: `(hits, misses)` since construction.
    /// Bypassing calls (`reschedule_function`) count as neither.
    pub fn schedule_cache_stats(&self) -> (u64, u64) {
        let cache = self.sched_cache.lock().unwrap();
        (cache.hits, cache.misses)
    }

    /// Drop every cached placement decision (registration changes, app
    /// reconfiguration, scheduler swaps, explicit rescheduling).
    pub(super) fn invalidate_schedule_cache(&self) {
        self.sched_cache.lock().unwrap().map.clear();
    }

    // ------------------------------------------------------ applications --

    /// Store a validated application (its DAG is built here). Scheduling
    /// happens separately in `configure_application` (functions.rs).
    pub(super) fn put_app(&self, config: AppConfig) -> anyhow::Result<Arc<Application>> {
        let dag = Dag::build(&config)?;
        let app = Arc::new(Application { config, dag });
        let name = app.config.application.clone();
        // Persist the DAG skeleton for crash recovery.
        let mut rec = Json::obj();
        rec.set(
            "functions",
            Json::Arr(
                app.config
                    .functions
                    .iter()
                    .map(|f| Json::Str(f.name.clone()))
                    .collect(),
            ),
        );
        self.kv.put("dag_store", &name, rec)?;
        self.apps.write().unwrap().insert(name, Arc::clone(&app));
        // Reconfiguration may change function configs under unchanged
        // names; cached decisions for the old configs must not survive.
        self.invalidate_schedule_cache();
        Ok(app)
    }

    pub fn app(&self, name: &str) -> anyhow::Result<Arc<Application>> {
        self.apps
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unknown application `{name}`"))
    }

    /// The EdgeFaaS function name: "ApplicationName.FunctionName" (§3.2.1).
    pub fn qualified(app: &str, function: &str) -> String {
        format!("{app}.{function}")
    }

    /// Candidate resources for a function (set at configure time).
    pub fn candidates_of(&self, app: &str, function: &str) -> anyhow::Result<Vec<ResourceId>> {
        self.candidates
            .read()
            .unwrap()
            .get(&Self::qualified(app, function))
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("function `{app}.{function}` has no candidates (configure the application first)"))
    }

    pub(super) fn set_candidates(
        &self,
        app: &str,
        function: &str,
        ids: Vec<ResourceId>,
    ) -> anyhow::Result<()> {
        let key = Self::qualified(app, function);
        let rec = Json::Arr(ids.iter().map(|&i| Json::Num(i as f64)).collect());
        self.kv.put("candidate_resource", &key, rec)?;
        self.candidates.write().unwrap().insert(key, ids);
        Ok(())
    }

    pub(super) fn remove_candidate(
        &self,
        app: &str,
        function: &str,
        id: ResourceId,
    ) -> anyhow::Result<()> {
        let key = Self::qualified(app, function);
        let mut map = self.candidates.write().unwrap();
        if let Some(ids) = map.get_mut(&key) {
            ids.retain(|&x| x != id);
            let rec = Json::Arr(ids.iter().map(|&i| Json::Num(i as f64)).collect());
            self.kv.put("candidate_resource", &key, rec)?;
        }
        Ok(())
    }
}

#[cfg(test)]
pub(crate) mod testkit {
    //! Test alias for the public paper testbed fixture.
    pub use crate::testbed::{paper_testbed, TestBed};
}

#[cfg(test)]
mod tests {
    use super::testkit::paper_testbed;
    use super::*;

    fn bed() -> testkit::TestBed {
        paper_testbed(Arc::new(RealClock::new()))
    }

    #[test]
    fn registers_the_paper_testbed() {
        let b = bed();
        assert_eq!(b.faas.resource_ids().len(), 11);
        assert_eq!(b.faas.tier_resources(Tier::Iot).len(), 8);
        assert_eq!(b.faas.tier_resources(Tier::Edge).len(), 2);
        assert_eq!(b.faas.tier_resources(Tier::Cloud), vec![b.cloud]);
    }

    #[test]
    fn latency_reflects_fig4() {
        let b = bed();
        // Pi set 1 -> edge 0 one-way ≈ 2.85 ms.
        let l = b.faas.latency(b.iot[0], b.edges[0]).unwrap();
        assert!((l - 0.00285).abs() < 1e-5, "{l}");
        // Pi set 2 -> edge 1 ≈ 0.3 ms.
        let l2 = b.faas.latency(b.iot[4], b.edges[1]).unwrap();
        assert!((l2 - 0.0003).abs() < 1e-5);
        // Set-2 path to cloud is much faster than set-1's.
        let c1 = b.faas.latency(b.iot[0], b.cloud).unwrap();
        let c2 = b.faas.latency(b.iot[4], b.cloud).unwrap();
        assert!(c2 < c1);
    }

    #[test]
    fn register_rejects_tier_mismatch() {
        let b = bed();
        let spec = ResourceSpec::paper_cloud("x:1");
        let handle = b.faas.resource(b.cloud).unwrap().handle.clone();
        // Net node 0 is an IoT node; claiming it's a cloud must fail.
        assert!(b.faas.register(spec, handle, 0).is_err());
    }

    #[test]
    fn unregister_blocks_until_clean_then_reuses_id() {
        let b = bed();
        let id = b.iot[7];
        let reg = b.faas.resource(id).unwrap();
        // Deploy a function -> unregister must fail.
        b.executor.register("img/x", |p: &[u8]| Ok(p.to_vec()));
        reg.handle.deploy("app.f", "img/x", 1 << 20, 0, &[]).unwrap();
        assert!(b.faas.unregister(id).is_err());
        reg.handle.remove("app.f").unwrap();
        // Store data -> unregister must fail.
        reg.handle.make_bucket("app.data").unwrap();
        reg.handle.put_object("app.data", "o", crate::util::bytes::Bytes::from("x")).unwrap();
        assert!(b.faas.unregister(id).is_err());
        reg.handle.remove_object("app.data", "o").unwrap();
        reg.handle.remove_bucket("app.data").unwrap();
        b.faas.unregister(id).unwrap();
        assert!(b.faas.resource(id).is_err());
        // The freed id is reused for the next registration.
        let spec = ResourceSpec::paper_iot("pi-new:8080");
        let new_id = b.faas.register(spec, reg.handle.clone(), reg.net_node).unwrap();
        assert_eq!(new_id, id, "resource ID is reused");
    }

    #[test]
    fn resource_map_backed_up() {
        let b = bed();
        assert_eq!(b.faas.kv.keys("resource_map").len(), 11);
        let rec = b.faas.kv.get("resource_map", &b.cloud.to_string()).unwrap();
        assert_eq!(rec.req_str("tier").unwrap(), "cloud");
    }

    #[test]
    fn qualified_names() {
        assert_eq!(EdgeFaaS::qualified("app", "fn"), "app.fn");
    }
}
