//! Function virtualization (§3.2.1): the EdgeFaaS verbs over virtual
//! function names.
//!
//! Functions live in per-application namespaces ("ApplicationName.
//! FunctionName"); users never see resource gateways. Deployment targets
//! the candidate resources chosen at application-configuration time and
//! recorded in the candidate_resource mapping.

use std::collections::HashMap;

use crate::util::bytes::Bytes;
use crate::util::json::Json;
use crate::util::threadpool::scoped_map;

use super::resource::{EdgeFaaS, ResourceId};
use super::scheduler::FunctionCreation;

/// The deployment package for one function. "The deployment package is a
/// .zip file archive that contains your OpenFaaS function code. For
/// FunctionPackage, the code property specifies the location of the .zip
/// file" — in this reproduction the code property names the executor image
/// that the per-resource backends resolve.
#[derive(Debug, Clone)]
pub struct FunctionPackage {
    pub code: String,
}

/// The placement plan produced by configuring an application.
pub type DeploymentPlan = HashMap<String, Vec<ResourceId>>;

impl EdgeFaaS {
    /// Configure an application (§3.2): parse + validate the Table-2 YAML,
    /// build the DAG, and run two-phase scheduling for every function in
    /// topological order. `data_locations` maps function names to the
    /// resources where their *input data* is generated (the anchors for
    /// `affinitytype: data`). Returns the full placement plan.
    pub fn configure_application(
        &self,
        yaml_text: &str,
        data_locations: &HashMap<String, Vec<ResourceId>>,
    ) -> anyhow::Result<DeploymentPlan> {
        let config = super::appconfig::AppConfig::from_yaml(&crate::util::yaml::parse(yaml_text)?)?;
        let app = self.put_app(config)?;
        let mut plan: DeploymentPlan = HashMap::new();
        for fname in &app.dag.topo_order {
            let f = app.config.function(fname).expect("topo name");
            // Dependency placements in topo order: every upstream instance
            // contributes its resource (duplicates preserved — each is a
            // separate data source for the locality policy).
            let mut dep_locations = Vec::new();
            for d in &f.dependencies {
                dep_locations
                    .extend(plan.get(d).cloned().unwrap_or_default());
            }
            let request = FunctionCreation {
                app: app.config.application.clone(),
                function: f.clone(),
                data_locations: data_locations.get(fname).cloned().unwrap_or_default(),
                dep_locations,
            };
            // Remember the data anchors so later reschedules (manual or the
            // auto-reschedule policy) can re-anchor data-affinity functions
            // without the caller re-supplying them.
            self.data_anchors
                .write()
                .unwrap()
                .insert(Self::qualified(&request.app, fname), request.data_locations.clone());
            let placed = self.schedule_function(&request)?;
            plan.insert(fname.clone(), placed);
        }
        Ok(plan)
    }

    /// The data anchors a function was configured with (empty if none).
    pub fn data_anchor(&self, app: &str, function: &str) -> Vec<ResourceId> {
        self.data_anchors
            .read()
            .unwrap()
            .get(&Self::qualified(app, function))
            .cloned()
            .unwrap_or_default()
    }

    /// The deployment package last used for a function
    /// ([`Self::deploy_function`] records it), if any.
    pub fn deployed_package(&self, app: &str, function: &str) -> Option<FunctionPackage> {
        self.packages.read().unwrap().get(&Self::qualified(app, function)).cloned()
    }

    /// Deploy_function(): build + deploy an EdgeFaaS function on its
    /// candidate resources. Partial failures remove the failed ids from the
    /// candidate mapping and return an error naming them (§3.2.1).
    pub fn deploy_function(
        &self,
        app: &str,
        function: &str,
        package: &FunctionPackage,
    ) -> anyhow::Result<()> {
        let application = self.app(app)?;
        let cfg = application
            .config
            .function(function)
            .ok_or_else(|| anyhow::anyhow!("no function `{function}` in `{app}`"))?;
        let candidates = self.candidates_of(app, function)?;
        let qname = Self::qualified(app, function);
        // Record the package (even on partial failure): it is what the
        // auto-reschedule policy redeploys with.
        self.packages.write().unwrap().insert(qname.clone(), package.clone());
        let labels =
            vec![("app".to_string(), app.to_string()), ("fn".to_string(), function.to_string())];
        let mut failed = Vec::new();
        for rid in &candidates {
            let reg = self.resource(*rid)?;
            if let Err(e) = reg.handle.deploy(
                &qname,
                &package.code,
                cfg.requirements.memory,
                cfg.requirements.gpu,
                &labels,
            ) {
                log::warn!("deploy {qname} on resource {rid} failed: {e}");
                failed.push((*rid, e.to_string()));
            }
        }
        for (rid, _) in &failed {
            self.remove_candidate(app, function, *rid)?;
        }
        if !failed.is_empty() {
            anyhow::bail!(
                "deploy `{qname}` failed on resources {:?}",
                failed.iter().map(|(r, _)| *r).collect::<Vec<_>>()
            );
        }
        Ok(())
    }

    /// Deploy every function of a configured application.
    /// `packages` maps function name -> package.
    pub fn deploy_application(
        &self,
        app: &str,
        packages: &HashMap<String, FunctionPackage>,
    ) -> anyhow::Result<()> {
        let application = self.app(app)?;
        for fname in &application.dag.topo_order {
            let package = packages
                .get(fname)
                .ok_or_else(|| anyhow::anyhow!("no package for function `{fname}`"))?;
            self.deploy_function(app, fname, package)?;
        }
        Ok(())
    }

    /// Delete_function(): remove from all deployed resources; returns the
    /// resources that failed to delete.
    pub fn delete_function(&self, app: &str, function: &str) -> anyhow::Result<()> {
        let candidates = self.candidates_of(app, function)?;
        let qname = Self::qualified(app, function);
        // Drop the reschedule bookkeeping with the deployment: a later
        // re-creation must not inherit this incarnation's package/anchors.
        self.packages.write().unwrap().remove(&qname);
        self.data_anchors.write().unwrap().remove(&qname);
        let mut failed = Vec::new();
        for rid in candidates {
            match self.resource(rid) {
                Ok(reg) => {
                    if let Err(e) = reg.handle.remove(&qname) {
                        failed.push((rid, e.to_string()));
                    }
                }
                Err(e) => failed.push((rid, e.to_string())),
            }
        }
        if !failed.is_empty() {
            anyhow::bail!("delete `{qname}` failed on {failed:?}");
        }
        Ok(())
    }

    /// Get_function(): where the function is deployed + per-resource specs.
    pub fn get_function(&self, app: &str, function: &str) -> anyhow::Result<Json> {
        let candidates = self.candidates_of(app, function)?;
        let qname = Self::qualified(app, function);
        let mut out = Json::obj();
        out.set("function", qname.as_str().into());
        out.set(
            "resources",
            Json::Arr(candidates.iter().map(|&r| Json::Num(r as f64)).collect()),
        );
        let mut statuses = Json::obj();
        for rid in candidates {
            let reg = self.resource(rid)?;
            match reg.handle.describe(&qname) {
                Ok(desc) => {
                    statuses.set(&rid.to_string(), desc);
                }
                Err(e) => {
                    let mut err = Json::obj();
                    err.set("error", e.to_string().as_str().into());
                    statuses.set(&rid.to_string(), err);
                }
            }
        }
        out.set("status", statuses);
        Ok(out)
    }

    /// List_functions(): all functions of the application with their info.
    pub fn list_functions(&self, app: &str) -> anyhow::Result<Json> {
        let application = self.app(app)?;
        let mut out = Json::obj();
        for fname in &application.dag.topo_order {
            out.set(fname, self.get_function(app, fname)?);
        }
        Ok(out)
    }

    /// Invoke(): run a function on its candidates. With `invoke_one`, only
    /// the first candidate is used. The payload is wrapped in an envelope
    /// carrying the scheduled resource ID (the paper: "The payload of the
    /// function is appended with the scheduled resource ID which is used in
    /// the notify_finish()"). Returns per-resource (id, output, latency).
    pub fn invoke(
        &self,
        app: &str,
        function: &str,
        payload: &Json,
        invoke_one: bool,
    ) -> anyhow::Result<Vec<(ResourceId, Bytes, f64)>> {
        let mut candidates = self.candidates_of(app, function)?;
        if invoke_one {
            candidates.truncate(1);
        }
        if candidates.is_empty() {
            anyhow::bail!("function `{app}.{function}` has no deployments");
        }
        let qname = Self::qualified(app, function);
        let work: Vec<(ResourceId, Bytes)> = candidates
            .iter()
            .map(|&rid| {
                let mut envelope = payload.clone();
                if let Json::Obj(_) = envelope {
                } else {
                    let mut o = Json::obj();
                    o.set("payload", envelope);
                    envelope = o;
                }
                envelope
                    .set("resource", (rid as u64).into())
                    .set("app", app.into())
                    .set("function", function.into());
                (rid, Bytes::from(envelope.to_string()))
            })
            .collect();
        // Fast path: a single instance needs no fan-out threads (spawning a
        // scoped worker costs ~10 µs — measurable against a warm sandbox).
        if work.len() == 1 {
            let (rid, envelope) = work.into_iter().next().unwrap();
            let reg = self.resource(rid)?;
            let (out, lat) = reg.handle.invoke(&qname, &envelope)?;
            return Ok(vec![(rid, out, lat)]);
        }
        let results = scoped_map(work, 8, |(rid, envelope)| {
            let reg = self.resource(rid)?;
            let (out, lat) = reg.handle.invoke(&qname, &envelope)?;
            Ok::<_, anyhow::Error>((rid, out, lat))
        });
        results.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::appconfig::federated_learning_yaml;
    use crate::coordinator::resource::testkit::paper_testbed;
    use crate::simnet::RealClock;
    use std::sync::Arc;

    fn configured_bed() -> (crate::coordinator::resource::testkit::TestBed, DeploymentPlan) {
        let b = paper_testbed(Arc::new(RealClock::new()));
        let mut data = HashMap::new();
        data.insert("train".to_string(), b.iot.clone());
        let plan = b.faas.configure_application(federated_learning_yaml(), &data).unwrap();
        (b, plan)
    }

    #[test]
    fn configure_produces_the_papers_fl_plan() {
        let (b, plan) = configured_bed();
        // §5.2: train on every Pi, firstaggregation on the two edges,
        // secondaggregation once on the cloud.
        assert_eq!(plan["train"], b.iot);
        assert_eq!(plan["firstaggregation"], b.edges);
        assert_eq!(plan["secondaggregation"], vec![b.cloud]);
    }

    #[test]
    fn deploy_invoke_delete_roundtrip() {
        let (b, _) = configured_bed();
        b.executor.register("img/train", |payload: &[u8]| {
            let v = crate::util::json::parse(std::str::from_utf8(payload)?)?;
            let mut out = Json::obj();
            out.set("echo_resource", v.get("resource").cloned().unwrap_or(Json::Null));
            Ok(out.to_string().into_bytes())
        });
        let pkg = FunctionPackage { code: "img/train".into() };
        b.faas.deploy_function("federatedlearning", "train", &pkg).unwrap();
        // Invoke on all 8 candidates.
        let results = b
            .faas
            .invoke("federatedlearning", "train", &Json::obj(), false)
            .unwrap();
        assert_eq!(results.len(), 8);
        for (rid, out, _lat) in &results {
            let v = crate::util::json::parse(std::str::from_utf8(out).unwrap()).unwrap();
            assert_eq!(
                v.get("echo_resource").unwrap().as_u64(),
                Some(*rid as u64),
                "envelope carries the scheduled resource id"
            );
        }
        // invoke_one hits exactly one.
        let one = b.faas.invoke("federatedlearning", "train", &Json::obj(), true).unwrap();
        assert_eq!(one.len(), 1);
        // get_function sees 8 deployments with invocation counts.
        let info = b.faas.get_function("federatedlearning", "train").unwrap();
        assert_eq!(info.get("resources").unwrap().as_arr().unwrap().len(), 8);
        b.faas.delete_function("federatedlearning", "train").unwrap();
        assert!(b.faas.invoke("federatedlearning", "train", &Json::obj(), false).is_err());
    }

    #[test]
    fn deploy_fails_cleanly_without_package_handler() {
        let (b, _) = configured_bed();
        // Deploy succeeds (backend accepts any image); invoking fails since
        // no handler is registered — but deployment of a *gpu-hungry*
        // function on a Pi fails at deploy time.
        let app = b.faas.app("federatedlearning").unwrap();
        assert!(app.config.function("train").unwrap().requirements.privacy);
    }

    #[test]
    fn deploy_unknown_function_errors() {
        let (b, _) = configured_bed();
        let pkg = FunctionPackage { code: "img/x".into() };
        assert!(b.faas.deploy_function("federatedlearning", "ghost", &pkg).is_err());
        assert!(b.faas.deploy_function("ghostapp", "train", &pkg).is_err());
    }

    #[test]
    fn list_functions_covers_dag() {
        let (b, _) = configured_bed();
        b.executor.register("img/any", |p: &[u8]| Ok(p.to_vec()));
        let pkg = FunctionPackage { code: "img/any".into() };
        let mut packages = HashMap::new();
        for f in ["train", "firstaggregation", "secondaggregation"] {
            packages.insert(f.to_string(), pkg.clone());
        }
        b.faas.deploy_application("federatedlearning", &packages).unwrap();
        let listing = b.faas.list_functions("federatedlearning").unwrap();
        for f in ["train", "firstaggregation", "secondaggregation"] {
            assert!(listing.get(f).is_some(), "missing {f}");
        }
    }
}
