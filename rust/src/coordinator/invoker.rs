//! Workflow invocation + chaining — the synchronous front-end over the
//! event-driven execution engine.
//!
//! "One function invokes the next function in the application is done
//! through the EdgeFaaS which has the information of the next function and
//! invokes from there" (§3.2.1). Entry functions fire on all their
//! placements, and as instances complete (notify_finish), dependents whose
//! dependencies are all done fire next. The DAG walk itself lives in
//! [`super::engine`]; [`EdgeFaaS::run_workflow`] is submit + await, so a
//! synchronous caller shares the dispatch queues, worker pool and
//! per-resource admission limits with every other in-flight run. Awaiting
//! parks on the run's own run-table shard (see [`super::engine`]'s
//! "Sharding & wakeups"), so N synchronous callers never form a
//! thundering herd on one condvar.
//!
//! Data flows by object URL: every function instance receives an envelope
//!
//! ```json
//! {"app": ..., "function": ..., "resource": <scheduled id>,
//!  "inputs": ["app/bucket/rid/object", ...]}
//! ```
//!
//! and returns `{"outputs": [urls...]}`. Routing between instances follows
//! locality: a dependency instance's outputs flow to the dependent instance
//! whose resource is network-closest to the producer (with `reduce: 1`
//! there is only one instance and it receives everything — the aggregation
//! barrier of the FL workflow).

use std::collections::HashMap;
use std::sync::Arc;

use crate::util::json::Json;

use super::engine::QoS;
use super::resource::{EdgeFaaS, ResourceId};

/// Result of one function instance within a workflow run.
#[derive(Debug, Clone)]
pub struct InstanceResult {
    pub resource: ResourceId,
    pub outputs: Vec<String>,
    /// Reported execution latency (gateway-measured), seconds.
    pub latency: f64,
}

/// Result of a whole workflow run.
#[derive(Debug, Clone, Default)]
pub struct WorkflowResult {
    /// function -> instance results, in placement order.
    pub functions: HashMap<String, Vec<InstanceResult>>,
    /// DAG nodes in the order the engine fired them. Nodes fire on
    /// dependency completion with ready sets sorted by topological index,
    /// so chain-shaped DAGs (both paper workflows) yield a fully
    /// deterministic order; DAGs with independent parallel branches may
    /// interleave branches differently across runs under wall-clock time.
    pub firing_order: Vec<String>,
    /// Wall-clock (or virtual) duration of the run, seconds.
    pub duration: f64,
}

impl WorkflowResult {
    /// Outputs of the DAG's sink functions.
    pub fn final_outputs(&self, sinks: &[&str]) -> Vec<String> {
        let mut out = Vec::new();
        for s in sinks {
            if let Some(instances) = self.functions.get(*s) {
                for i in instances {
                    out.extend(i.outputs.iter().cloned());
                }
            }
        }
        out
    }
}

impl EdgeFaaS {
    /// Run a full workflow synchronously: invoke the entrypoints and chain
    /// the DAG until every function has completed. `entry_inputs` provides
    /// initial object URLs per entry function (empty when sources generate
    /// their own data).
    ///
    /// Front-end over the engine: equivalent to
    /// [`submit_workflow`](Self::submit_workflow) +
    /// [`wait_workflow`](Self::wait_workflow), and therefore safe to call
    /// from many threads at once — the runs interleave. Submits under the
    /// default [`QoS`] (`Interactive`, no deadline); see
    /// [`run_workflow_qos`](Self::run_workflow_qos).
    pub fn run_workflow(
        self: &Arc<Self>,
        app: &str,
        entry_inputs: &HashMap<String, Vec<String>>,
    ) -> anyhow::Result<WorkflowResult> {
        self.run_workflow_qos(app, entry_inputs, QoS::default())
    }

    /// [`run_workflow`](Self::run_workflow) under an explicit [`QoS`]: the
    /// class and deadline govern the run's position in the engine's
    /// priority queue, its backpressure treatment, and deadline
    /// enforcement (see [`super::engine`]'s module docs). The typed errors
    /// — [`super::engine::EngineError`] on admission,
    /// [`super::engine::WaitError`] on completion — flatten into the
    /// returned `anyhow::Error`; callers that need to branch on them
    /// should use `submit_workflow_qos` + `wait_workflow` directly.
    pub fn run_workflow_qos(
        self: &Arc<Self>,
        app: &str,
        entry_inputs: &HashMap<String, Vec<String>>,
        qos: QoS,
    ) -> anyhow::Result<WorkflowResult> {
        let run = self.submit_workflow_qos(app, entry_inputs, qos)?;
        Ok(self.wait_workflow(run, f64::INFINITY)?)
    }

    /// Compute each instance's input URLs: entry inputs are split by the
    /// bucket-owning resource when possible; dependency outputs flow to the
    /// network-closest dependent instance.
    pub(super) fn route_inputs(
        &self,
        app: &str,
        fname: &str,
        placements: &[ResourceId],
        entry_inputs: &HashMap<String, Vec<String>>,
        sofar: &WorkflowResult,
    ) -> anyhow::Result<Vec<Vec<String>>> {
        let application = self.app(app)?;
        let deps = application
            .dag
            .dependencies
            .get(fname)
            .cloned()
            .unwrap_or_default();
        let mut per_instance: Vec<Vec<String>> = vec![Vec::new(); placements.len()];

        // Entry inputs: route each URL to the instance closest to the
        // object's resident resource.
        if let Some(urls) = entry_inputs.get(fname) {
            for url in urls {
                let parsed = super::storage::ObjectUrl::parse(url)?;
                let idx = self.closest_instance(parsed.resource, placements)?;
                per_instance[idx].push(url.clone());
            }
        }
        // Dependency outputs.
        for dep in &deps {
            let instances = sofar
                .functions
                .get(dep)
                .ok_or_else(|| anyhow::anyhow!("dependency `{dep}` has no results yet"))?;
            for inst in instances {
                let idx = self.closest_instance(inst.resource, placements)?;
                per_instance[idx].extend(inst.outputs.iter().cloned());
            }
        }
        Ok(per_instance)
    }

    /// Index of the placement whose resource is closest to `from`.
    fn closest_instance(
        &self,
        from: ResourceId,
        placements: &[ResourceId],
    ) -> anyhow::Result<usize> {
        if placements.is_empty() {
            anyhow::bail!("no placements");
        }
        let mut best = 0;
        let mut best_lat = f64::INFINITY;
        for (i, &p) in placements.iter().enumerate() {
            let lat = self.latency(from, p).unwrap_or(f64::INFINITY);
            if lat < best_lat {
                best_lat = lat;
                best = i;
            }
        }
        Ok(best)
    }
}

/// Parse a function's response envelope: `{"outputs": ["url", ...]}`.
///
/// Shared by the engine's local dispatch path and the federation plane's
/// stolen-instance execution ([`super::federation`]), so a thief's view of
/// an invocation outcome is byte-for-byte the victim's.
pub(super) fn parse_outputs(raw: &[u8]) -> anyhow::Result<Vec<String>> {
    if raw.is_empty() {
        return Ok(Vec::new());
    }
    let v = crate::util::json::parse(std::str::from_utf8(raw)?)?;
    Ok(v.get("outputs")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(|u| u.as_str().map(String::from)).collect())
        .unwrap_or_default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::appconfig::federated_learning_yaml;
    use crate::coordinator::functions::FunctionPackage;
    use crate::coordinator::resource::testkit::paper_testbed;
    use crate::simnet::RealClock;
    use std::sync::Arc;

    /// End-to-end DAG chaining over the FL topology with counting handlers:
    /// each stage writes one object per invocation and returns its URL.
    #[test]
    fn fl_workflow_chains_with_locality_routing() {
        let b = paper_testbed(Arc::new(RealClock::new()));
        let faas = Arc::clone(&b.faas);
        let app = "federatedlearning";

        // Buckets for the intermediate models, one per edge + cloud.
        faas.create_bucket(app, "models", Some(b.edges[0])).unwrap();

        // train: writes a "model" object named after its resource.
        {
            let faas = Arc::clone(&faas);
            b.executor.register("img/train", move |payload: &[u8]| {
                let v = crate::util::json::parse(std::str::from_utf8(payload)?)?;
                let rid = v.get("resource").unwrap().as_u64().unwrap();
                let obj = format!("model-{rid}.bin");
                let url = faas.put_object("federatedlearning", "models", &obj, &rid.to_le_bytes())?;
                let mut out = Json::obj();
                out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
                Ok(out.to_string().into_bytes())
            });
        }
        // aggregators: count inputs, write an aggregate object.
        for img in ["img/agg1", "img/agg2"] {
            let faas = Arc::clone(&faas);
            let img_name = img.to_string();
            b.executor.register(img, move |payload: &[u8]| {
                let v = crate::util::json::parse(std::str::from_utf8(payload)?)?;
                let rid = v.get("resource").unwrap().as_u64().unwrap();
                let inputs = v.get("inputs").unwrap().as_arr().unwrap();
                let obj = format!("{}-{rid}-n{}.bin", img_name.replace('/', "-"), inputs.len());
                let url =
                    faas.put_object("federatedlearning", "models", &obj, &[inputs.len() as u8])?;
                let mut out = Json::obj();
                out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
                Ok(out.to_string().into_bytes())
            });
        }

        let mut data = HashMap::new();
        data.insert("train".to_string(), b.iot.clone());
        faas.configure_application(federated_learning_yaml(), &data).unwrap();
        let mut packages = HashMap::new();
        packages.insert("train".into(), FunctionPackage { code: "img/train".into() });
        packages.insert("firstaggregation".into(), FunctionPackage { code: "img/agg1".into() });
        packages.insert("secondaggregation".into(), FunctionPackage { code: "img/agg2".into() });
        faas.deploy_application(app, &packages).unwrap();

        let result = faas.run_workflow(app, &HashMap::new()).unwrap();

        // 8 train instances, 2 first-level aggregations, 1 second-level.
        assert_eq!(result.functions["train"].len(), 8);
        assert_eq!(result.functions["firstaggregation"].len(), 2);
        assert_eq!(result.functions["secondaggregation"].len(), 1);
        // The engine fired the chain in dependency order.
        assert_eq!(
            result.firing_order,
            vec!["train", "firstaggregation", "secondaggregation"]
        );
        // Locality routing: each edge aggregator got exactly its set's 4
        // models (encoded in the object name).
        for inst in &result.functions["firstaggregation"] {
            assert_eq!(inst.outputs.len(), 1);
            assert!(
                inst.outputs[0].contains("-n4.bin"),
                "each edge aggregates its 4 local models: {:?}",
                inst.outputs
            );
        }
        // The cloud aggregator saw both partial aggregates.
        let cloud_inst = &result.functions["secondaggregation"][0];
        assert_eq!(cloud_inst.resource, b.cloud);
        assert!(cloud_inst.outputs[0].contains("-n2.bin"));
        assert!(result.duration >= 0.0);
    }

    #[test]
    fn entry_inputs_route_to_closest_instance() {
        let b = paper_testbed(Arc::new(RealClock::new()));
        let faas = Arc::clone(&b.faas);
        // Single-function app on the two edges.
        let yaml = "\
application: routing
entrypoint: f
dag:
  - name: f
    affinity:
      nodetype: edge
      affinitytype: data
    reduce: auto
";
        let mut data = HashMap::new();
        data.insert("f".to_string(), vec![b.iot[0], b.iot[4]]);
        let plan = faas.configure_application(yaml, &data).unwrap();
        assert_eq!(plan["f"], b.edges);
        {
            let _ = &b.executor;
            b.executor.register("img/echo-inputs", |payload: &[u8]| {
                let v = crate::util::json::parse(std::str::from_utf8(payload)?)?;
                let inputs = v.get("inputs").cloned().unwrap_or(Json::Arr(vec![]));
                let mut out = Json::obj();
                // Echo inputs back as outputs to observe the routing.
                out.set("outputs", inputs);
                Ok(out.to_string().into_bytes())
            });
        }
        faas.deploy_function("routing", "f", &FunctionPackage { code: "img/echo-inputs".into() })
            .unwrap();
        // Objects on a set-1 Pi and a set-2 Pi.
        faas.create_bucket("routing", "in1", Some(b.iot[0])).unwrap();
        faas.create_bucket("routing", "in2", Some(b.iot[4])).unwrap();
        let u1 = faas.put_object("routing", "in1", "a", b"1").unwrap().to_string();
        let u2 = faas.put_object("routing", "in2", "b", b"2").unwrap().to_string();
        let mut entry = HashMap::new();
        entry.insert("f".to_string(), vec![u1.clone(), u2.clone()]);
        let result = faas.run_workflow("routing", &entry).unwrap();
        let f = &result.functions["f"];
        assert_eq!(f.len(), 2);
        // Instance on edge0 (set 1) must have received u1; edge1 got u2.
        let by_resource: HashMap<ResourceId, &InstanceResult> =
            f.iter().map(|i| (i.resource, i)).collect();
        assert_eq!(by_resource[&b.edges[0]].outputs, vec![u1]);
        assert_eq!(by_resource[&b.edges[1]].outputs, vec![u2]);
    }
}
