//! Federated-learning workflow (§4.2): LeNet-5 on per-device digit shards
//! with two-level FedAvg aggregation.
//!
//! "Each IoT device trains the model locally using the local generated
//! data. It then passes the trained model to edge cluster for aggregation.
//! The edge aggregated model is finally contributed to cloud for final
//! aggregation." (§1)
//!
//! Functions (registered as executor images):
//! * `fl/train` — load the local shard + incoming global model, run
//!   `local_steps` SGD mini-batches through the `lenet_train_step` artifact,
//!   publish the trained model (sample count encoded in the object name).
//! * `fl/agg1` — stack ≤4 worker models, run `fedavg_k4`.
//! * `fl/agg2` — stack the 2 edge aggregates, run `fedavg_k2`.
//!
//! The paper's MNIST is replaced by a deterministic synthetic digit corpus
//! (see DESIGN.md §Substitutions): 8x8-bitmap digit glyphs upsampled to
//! 28x28 with random shift + noise — a learnable 10-class problem with the
//! same tensor geometry.

use std::collections::HashMap;
use std::sync::Arc;

use crate::cluster::NativeExecutor;
use crate::coordinator::functions::FunctionPackage;
use crate::coordinator::{EdgeFaaS, Priority, QoS, ResourceId};
use crate::runtime::{EngineService, Tensor};
use crate::util::rng::Pcg32;

use super::common::{outputs_json, pack_tensors, parse_envelope, unpack_tensors};

/// LeNet-5 flat parameter count (matches python/compile/model.py).
pub const LENET_PARAMS: usize = 61706;

/// Per-layer (size, He scale) of the flat layout — mirrors LENET_SHAPES.
const LENET_LAYERS: [(usize, f32); 10] = [
    (150, 0.283),   // conv1_w  sqrt(2/25)
    (6, 0.0),       // conv1_b
    (2400, 0.1155), // conv2_w  sqrt(2/150)
    (16, 0.0),      // conv2_b
    (48000, 0.0707),
    (120, 0.0),
    (10080, 0.1291),
    (84, 0.0),
    (840, 0.1543),
    (10, 0.0),
];

/// He-initialized flat LeNet parameter vector (deterministic per seed).
pub fn lenet_init(seed: u64) -> Tensor {
    let mut rng = Pcg32::seeded(seed);
    let mut params = Vec::with_capacity(LENET_PARAMS);
    for (n, scale) in LENET_LAYERS {
        for _ in 0..n {
            params.push(rng.next_gaussian() as f32 * scale);
        }
    }
    debug_assert_eq!(params.len(), LENET_PARAMS);
    Tensor::f32(vec![LENET_PARAMS], params).unwrap()
}

// ------------------------------------------------------- synthetic digits --

/// 8x8 bitmap glyphs for the digits 0-9 (classic console font subset).
const GLYPHS: [u64; 10] = [
    0x3c66666e76663c00, // 0
    0x1818381818187e00, // 1
    0x3c66060c30607e00, // 2
    0x3c66061c06663c00, // 3
    0x060e1e667f060600, // 4
    0x7e607c0606663c00, // 5
    0x3c66607c66663c00, // 6
    0x7e66060c18181800, // 7
    0x3c66663c66663c00, // 8
    0x3c66663e06663c00, // 9
];

/// Render one digit as a 28x28 image with a random ±2px shift and noise.
pub fn render_digit(digit: usize, rng: &mut Pcg32) -> Vec<f32> {
    let glyph = GLYPHS[digit];
    let mut img = vec![0.0f32; 28 * 28];
    let dy = rng.range(0, 5) as i32 - 2;
    let dx = rng.range(0, 5) as i32 - 2;
    for gy in 0..8 {
        for gx in 0..8 {
            let bit = (glyph >> (63 - (gy * 8 + gx))) & 1;
            if bit == 1 {
                // Upsample each glyph pixel to a 3x3 block, centered.
                for sy in 0..3 {
                    for sx in 0..3 {
                        let y = 2 + gy as i32 * 3 + sy + dy;
                        let x = 2 + gx as i32 * 3 + sx + dx;
                        if (0..28).contains(&y) && (0..28).contains(&x) {
                            img[(y * 28 + x) as usize] = 1.0;
                        }
                    }
                }
            }
        }
    }
    for p in img.iter_mut() {
        *p = (*p + 0.08 * rng.next_gaussian() as f32).clamp(0.0, 1.0);
    }
    img
}

/// A labelled shard of `n` synthetic digits: (images [n,1,28,28], labels [n]).
pub fn digit_shard(n: usize, seed: u64) -> (Tensor, Tensor) {
    let mut rng = Pcg32::seeded(seed);
    let mut images = Vec::with_capacity(n * 784);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let d = rng.next_below(10) as usize;
        labels.push(d as i32);
        images.extend(render_digit(d, &mut rng));
    }
    (
        Tensor::f32(vec![n, 1, 28, 28], images).unwrap(),
        Tensor::i32(vec![n], labels).unwrap(),
    )
}

// ------------------------------------------------------------ the handlers --

/// Configuration for the FL handlers.
#[derive(Debug, Clone)]
pub struct FlConfig {
    /// Local SGD steps per round per worker.
    pub local_steps: usize,
    /// Mini-batch size (must equal the artifact's TRAIN_BATCH).
    pub batch: usize,
    pub lr: f32,
    /// Samples per device shard.
    pub shard_size: usize,
}

impl Default for FlConfig {
    fn default() -> Self {
        FlConfig { local_steps: 4, batch: 32, lr: 0.15, shard_size: 128 }
    }
}

/// The application name used by all FL objects.
pub const APP: &str = "federatedlearning";

/// The QoS class FL training rounds submit under: federated learning is
/// throughput-oriented background work (a round taking longer costs
/// nothing but wall time), so it rides the `Batch` class — yielding slots
/// to latency-sensitive workflows and being shed first under backpressure.
pub fn default_qos() -> QoS {
    QoS::class(Priority::Batch)
}

/// Bucket holding each device's local shard: `shard-<rid>`.
pub fn shard_bucket(rid: ResourceId) -> String {
    format!("shard-{rid}")
}

/// Bucket holding in-flight models: one per tier resource.
pub fn model_bucket(rid: ResourceId) -> String {
    format!("models-{rid}"            )
}

/// Seed every IoT device's shard into its local bucket (data locality:
/// "when data is generated from IoT devices, the data is stored on IoT
/// devices"). Returns the shard URLs.
pub fn seed_shards(
    faas: &EdgeFaaS,
    iot: &[ResourceId],
    cfg: &FlConfig,
    seed: u64,
) -> anyhow::Result<Vec<String>> {
    let mut urls = Vec::new();
    for (i, &rid) in iot.iter().enumerate() {
        let bucket = shard_bucket(rid);
        faas.create_bucket(APP, &bucket, Some(rid))?;
        let (images, labels) = digit_shard(cfg.shard_size, seed.wrapping_add(i as u64 * 7919));
        let url = faas.put_object(APP, &bucket, "shard.bin", &pack_tensors(&[images, labels]))?;
        urls.push(url.to_string());
    }
    Ok(urls)
}

/// Create the per-resource model buckets (workers, edges, cloud).
pub fn create_model_buckets(faas: &EdgeFaaS, resources: &[ResourceId]) -> anyhow::Result<()> {
    for &rid in resources {
        faas.create_bucket(APP, &model_bucket(rid), Some(rid))?;
    }
    Ok(())
}

/// The deployment packages of the three FL functions (shared by the
/// example, the integration tests and the benches).
pub fn fl_packages() -> HashMap<String, FunctionPackage> {
    let mut packages = HashMap::new();
    packages.insert("train".to_string(), FunctionPackage { code: "fl/train".into() });
    packages.insert("firstaggregation".to_string(), FunctionPackage { code: "fl/agg1".into() });
    packages.insert("secondaggregation".to_string(), FunctionPackage { code: "fl/agg2".into() });
    packages
}

/// Start one federated round: place `global` into every worker's model
/// bucket ("the aggregator sends the shared model back to each of the
/// workers") and return the entry-input URLs for `train`.
pub fn distribute_global(
    faas: &EdgeFaaS,
    iot: &[ResourceId],
    round: usize,
    global: &Tensor,
) -> anyhow::Result<Vec<String>> {
    let mut urls = Vec::new();
    for &rid in iot {
        let url = faas.put_object(
            APP,
            &model_bucket(rid),
            &format!("global-r{round}.bin"),
            &global.to_bytes(),
        )?;
        urls.push(url.to_string());
    }
    Ok(urls)
}

/// Extract the sample-count weight encoded in a model object name
/// (`model-...-n<count>.bin`).
fn weight_of(url: &str) -> f32 {
    url.rsplit_once("-n")
        .and_then(|(_, tail)| tail.strip_suffix(".bin"))
        .and_then(|n| n.parse::<f32>().ok())
        .unwrap_or(1.0)
}

/// Register the three FL handlers on an executor.
pub fn register_handlers(
    executor: &NativeExecutor,
    engine: Arc<EngineService>,
    faas: Arc<EdgeFaaS>,
    cfg: FlConfig,
) {
    // ---- fl/train ----
    {
        let engine = Arc::clone(&engine);
        let faas = Arc::clone(&faas);
        let cfg = cfg.clone();
        executor.register("fl/train", move |payload: &[u8]| {
            let env = parse_envelope(payload)?;
            let rid = env.resource;
            // Inputs: the incoming global model (routed to this worker).
            // The local shard comes from the device's own bucket.
            let model_url = env
                .inputs
                .first()
                .ok_or_else(|| anyhow::anyhow!("train: no incoming model url"))?;
            let mut params = Tensor::from_bytes(&faas.get_object_url(model_url)?)?;
            let shard_raw = faas.get_object_url(&format!(
                "{APP}/{}/{rid}/shard.bin",
                shard_bucket(rid)
            ))?;
            let shard = unpack_tensors(&shard_raw)?;
            let (images, labels) = (&shard[0], &shard[1]);
            let n = images.shape[0];
            anyhow::ensure!(labels.shape == vec![n], "shard labels mismatch");
            // Mini-batch SGD: deterministic batch starts per (rid, step).
            let mut rng = Pcg32::seeded(rid as u64 * 31 + 17);
            let mut last_loss = f32::NAN;
            for _ in 0..cfg.local_steps {
                let start = rng.range(0, n.saturating_sub(cfg.batch).max(1));
                let img_slice = slice_batch(images, start, cfg.batch)?;
                let lbl_slice = slice_labels(labels, start, cfg.batch)?;
                let out = engine.execute(
                    "lenet_train_step",
                    &[params, img_slice, lbl_slice, Tensor::scalar(cfg.lr)],
                )?;
                params = out[0].clone();
                last_loss = out[1].item()?;
            }
            log::debug!("train on {rid}: loss {last_loss:.4}");
            let obj = format!("model-{rid}-n{}.bin", n);
            let url = faas.put_object(APP, &model_bucket(rid), &obj, &params.to_bytes())?;
            Ok(outputs_json(&[url.to_string()]))
        });
    }
    // ---- fl/agg1 (edge, K<=4) and fl/agg2 (cloud, K<=2) ----
    for (image, entry, k) in [("fl/agg1", "fedavg_k4", 4usize), ("fl/agg2", "fedavg_k2", 2usize)] {
        let engine = Arc::clone(&engine);
        let faas = Arc::clone(&faas);
        executor.register(image, move |payload: &[u8]| {
            let env = parse_envelope(payload)?;
            anyhow::ensure!(!env.inputs.is_empty(), "aggregator got no models");
            anyhow::ensure!(
                env.inputs.len() <= k,
                "aggregator got {} models, artifact takes {k}",
                env.inputs.len()
            );
            let mut stacked = Vec::with_capacity(k * LENET_PARAMS);
            let mut weights = vec![0.0f32; k];
            let mut total_samples = 0f32;
            for (i, url) in env.inputs.iter().enumerate() {
                let t = Tensor::from_bytes(&faas.get_object_url(url)?)?;
                anyhow::ensure!(t.shape == vec![LENET_PARAMS], "bad model shape {:?}", t.shape);
                stacked.extend_from_slice(t.as_f32()?);
                weights[i] = weight_of(url);
                total_samples += weights[i];
            }
            // Pad missing workers with zero weight (their rows are zeros).
            while stacked.len() < k * LENET_PARAMS {
                stacked.extend(std::iter::repeat(0.0).take(LENET_PARAMS));
            }
            let out = engine.execute(
                entry,
                &[
                    Tensor::f32(vec![k, LENET_PARAMS], stacked)?,
                    Tensor::f32(vec![k], weights)?,
                ],
            )?;
            let obj = format!("model-agg{}-n{}.bin", env.resource, total_samples as u64);
            let url =
                faas.put_object(APP, &model_bucket(env.resource), &obj, &out[0].to_bytes())?;
            Ok(outputs_json(&[url.to_string()]))
        });
    }
}

/// Slice `count` images starting at `start` (clamped) from [N,1,28,28].
fn slice_batch(images: &Tensor, start: usize, count: usize) -> anyhow::Result<Tensor> {
    let n = images.shape[0];
    let start = start.min(n.saturating_sub(count));
    let data = images.as_f32()?;
    let stride = 784;
    Tensor::f32(
        vec![count, 1, 28, 28],
        data[start * stride..(start + count) * stride].to_vec(),
    )
}

fn slice_labels(labels: &Tensor, start: usize, count: usize) -> anyhow::Result<Tensor> {
    let n = labels.shape[0];
    let start = start.min(n.saturating_sub(count));
    let data = labels.as_i32()?;
    Tensor::i32(vec![count], data[start..start + count].to_vec())
}

/// Evaluate a model's accuracy on a held-out shard via `lenet_predict`.
pub fn evaluate(engine: &EngineService, params: &Tensor, seed: u64, batches: usize) -> anyhow::Result<f64> {
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in 0..batches {
        let (images, labels) = digit_shard(32, seed.wrapping_add(b as u64 * 131));
        let out = engine.execute("lenet_predict", &[params.clone(), images])?;
        let preds = out[0].as_i32()?;
        let truth = labels.as_i32()?;
        correct += preds.iter().zip(truth).filter(|(p, t)| p == t).count();
        total += truth.len();
    }
    Ok(correct as f64 / total as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_init_layout() {
        let t = lenet_init(0);
        assert_eq!(t.shape, vec![LENET_PARAMS]);
        let p = t.as_f32().unwrap();
        // Biases (offsets 150..156) are zero; conv1 weights are not.
        assert!(p[..150].iter().any(|&x| x != 0.0));
        assert!(p[150..156].iter().all(|&x| x == 0.0));
        // Deterministic per seed.
        assert_eq!(lenet_init(1), lenet_init(1));
        assert_ne!(lenet_init(1), lenet_init(2));
    }

    #[test]
    fn digit_shard_is_deterministic_and_labelled() {
        let (img_a, lbl_a) = digit_shard(64, 9);
        let (img_b, lbl_b) = digit_shard(64, 9);
        assert_eq!(img_a, img_b);
        assert_eq!(lbl_a, lbl_b);
        assert_eq!(img_a.shape, vec![64, 1, 28, 28]);
        let labels = lbl_a.as_i32().unwrap();
        assert!(labels.iter().all(|&l| (0..10).contains(&l)));
        // All ten classes appear in 64 draws with overwhelming probability.
        let classes: std::collections::HashSet<i32> = labels.iter().copied().collect();
        assert!(classes.len() >= 8, "classes: {classes:?}");
    }

    #[test]
    fn rendered_digits_differ_by_class() {
        let mut rng = Pcg32::seeded(4);
        let a = render_digit(0, &mut rng);
        let mut rng = Pcg32::seeded(4);
        let b = render_digit(1, &mut rng);
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 10.0, "glyphs must differ: {diff}");
    }

    #[test]
    fn weight_encoding_roundtrip() {
        assert_eq!(weight_of("fl/models-3/3/model-3-n128.bin"), 128.0);
        assert_eq!(weight_of("fl/models-9/9/model-agg9-n512.bin"), 512.0);
        assert_eq!(weight_of("no-weight-here"), 1.0);
    }

    #[test]
    fn batch_slicing_clamps() {
        let (images, labels) = digit_shard(40, 0);
        let b = slice_batch(&images, 38, 32).unwrap();
        assert_eq!(b.shape, vec![32, 1, 28, 28]);
        let l = slice_labels(&labels, 38, 32).unwrap();
        assert_eq!(l.shape, vec![32]);
        // Clamped window = rows 8..40.
        assert_eq!(l.as_i32().unwrap(), &labels.as_i32().unwrap()[8..40]);
    }
}
