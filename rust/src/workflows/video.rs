//! Video-analytics workflow (§4.1): the six-stage pipeline from camera to
//! identity, with every ML stage running through the PJRT artifacts.
//!
//! Stage handlers (executor images):
//! * `video/video-generator`  — synthesize a GoP from the device camera
//!   (moving "face" blob over a textured background, deterministic per
//!   device + GoP index), store it locally (data locality).
//! * `video/video-processing` — FFmpeg stand-in: normalize + chunk into the
//!   GoP tensor the downstream stages consume.
//! * `video/motion-detection` — the Pallas `motion_scores` kernel; GoPs
//!   whose every inter-frame score is below threshold are dropped.
//! * `video/face-detection`   — `face_detect` template correlation; keeps
//!   frames whose best window clears the detection threshold.
//! * `video/face-extraction`  — `face_extract` crops the detected windows.
//! * `video/face-recognition` — `face_embed` + `knn_classify` against the
//!   enrolled gallery; outputs identity labels.

use std::collections::HashMap;
use std::sync::Arc;

use crate::cluster::NativeExecutor;
use crate::coordinator::functions::FunctionPackage;
use crate::coordinator::{EdgeFaaS, Priority, QoS, ResourceId};
use crate::runtime::{EngineService, Tensor};
use crate::util::rng::Pcg32;

use super::common::{outputs_json, pack_tensors, parse_envelope, unpack_tensors};

/// Frame geometry — must match python/compile/aot.py.
pub const FRAME_H: usize = 96;
pub const FRAME_W: usize = 160;
pub const GOP: usize = 24;
pub const DETECT_BATCH: usize = 8;
pub const WIN: usize = 32;
pub const EMBED_DIM: usize = 64;
pub const GALLERY: usize = 32;

/// The application name used by all video objects.
pub const APP: &str = "videopipeline";

/// The QoS class video-analytics runs submit under: a live camera pipeline
/// is latency-critical (a GoP analyzed late is a GoP analyzed never), so
/// it rides the `Realtime` class and jumps queued `Interactive`/`Batch`
/// work. No default deadline — attach one per deployment with
/// [`QoS::with_deadline`] when frames may be dropped.
pub fn default_qos() -> QoS {
    QoS::class(Priority::Realtime)
}

/// The six pipeline stages, in DAG order.
pub const STAGES: [&str; 6] = [
    "video-generator",
    "video-processing",
    "motion-detection",
    "face-detection",
    "face-extraction",
    "face-recognition",
];

/// The deployment packages of the six stages (shared by the example, the
/// integration tests and the benches).
pub fn video_packages() -> HashMap<String, FunctionPackage> {
    STAGES
        .iter()
        .map(|s| (s.to_string(), FunctionPackage { code: format!("video/{s}") }))
        .collect()
}

/// Per-resource bucket for pipeline data.
pub fn bucket(rid: ResourceId) -> String {
    format!("video-{rid}")
}

// --------------------------------------------------------- synth "camera" --

/// Draw the synthetic face blob (must match the python template family).
fn draw_face(img: &mut [f32], cy: f32, cx: f32, identity_scale: f32) {
    for y in 0..FRAME_H {
        for x in 0..FRAME_W {
            let dy = (y as f32 - cy) / (10.0 * identity_scale);
            let dx = (x as f32 - cx) / (9.0 * identity_scale);
            let face = (-(dy * dy + dx * dx)).exp();
            let mut v = face;
            for (ey, ex) in [(-4.0f32, -4.0f32), (-4.0, 4.0)] {
                let ddy = y as f32 - cy - ey;
                let ddx = x as f32 - cx - ex;
                v -= 0.8 * (-(ddy * ddy + ddx * ddx) / 6.0).exp();
            }
            img[y * FRAME_W + x] = (img[y * FRAME_W + x] + v).clamp(0.0, 1.0);
        }
    }
}

/// Synthesize one GoP: a face with `identity` moving across a textured
/// background. `motion=false` renders a static scene (motion-detection
/// negative). Deterministic per (camera_seed, gop_index).
pub fn synth_gop(camera_seed: u64, gop_index: u64, identity: usize, motion: bool) -> Tensor {
    let mut rng = Pcg32::new(camera_seed, gop_index.wrapping_mul(2654435761).wrapping_add(1));
    let mut frames = Vec::with_capacity(GOP * FRAME_H * FRAME_W);
    let base_y = 24.0 + rng.next_f32() * 40.0;
    let base_x = 24.0 + rng.next_f32() * 100.0;
    let vy = if motion { (rng.next_f32() - 0.5) * 3.0 } else { 0.0 };
    let vx = if motion { 1.0 + rng.next_f32() * 2.0 } else { 0.0 };
    let identity_scale = 0.8 + 0.1 * (identity % 5) as f32;
    // Shared static background texture.
    let mut bg = vec![0.0f32; FRAME_H * FRAME_W];
    for p in bg.iter_mut() {
        *p = rng.next_f32() * 0.1;
    }
    for t in 0..GOP {
        let mut img = bg.clone();
        let cy = (base_y + vy * t as f32).clamp(18.0, FRAME_H as f32 - 18.0);
        let cx = (base_x + vx * t as f32).clamp(18.0, FRAME_W as f32 - 18.0);
        draw_face(&mut img, cy, cx, identity_scale);
        frames.extend_from_slice(&img);
    }
    Tensor::f32(vec![GOP, FRAME_H, FRAME_W], frames).unwrap()
}

/// Enroll a gallery: `GALLERY` identity crops -> embeddings via the engine.
/// Returns (embeddings [G, D], labels [G]).
pub fn enroll_gallery(engine: &EngineService, seed: u64) -> anyhow::Result<(Tensor, Tensor)> {
    let mut embeddings = Vec::with_capacity(GALLERY * EMBED_DIM);
    let mut labels = Vec::with_capacity(GALLERY);
    // Batch enrolment through the face_embed artifact (batch = 8).
    let mut rng = Pcg32::seeded(seed);
    for chunk in 0..(GALLERY / DETECT_BATCH) {
        let mut patches = Vec::with_capacity(DETECT_BATCH * WIN * WIN);
        for i in 0..DETECT_BATCH {
            let identity = chunk * DETECT_BATCH + i;
            let mut img = vec![0.0f32; WIN * WIN];
            for p in img.iter_mut() {
                *p = rng.next_f32() * 0.1;
            }
            // Crop-sized face with the identity's scale, centered.
            let scale = 0.8 + 0.1 * (identity % 5) as f32;
            for y in 0..WIN {
                for x in 0..WIN {
                    let dy = (y as f32 - 16.0) / (10.0 * scale);
                    let dx = (x as f32 - 16.0) / (9.0 * scale);
                    let mut v = (-(dy * dy + dx * dx)).exp();
                    for (ey, ex) in [(-4.0f32, -4.0f32), (-4.0, 4.0)] {
                        let ddy = y as f32 - 16.0 - ey;
                        let ddx = x as f32 - 16.0 - ex;
                        v -= 0.8 * (-(ddy * ddy + ddx * ddx) / 6.0).exp();
                    }
                    img[y * WIN + x] = (img[y * WIN + x] + v).clamp(0.0, 1.0);
                }
            }
            patches.extend(img);
            labels.push((identity % 10) as i32);
        }
        let out = engine.execute(
            "face_embed",
            &[Tensor::f32(vec![DETECT_BATCH, WIN, WIN], patches)?],
        )?;
        embeddings.extend_from_slice(out[0].as_f32()?);
    }
    Ok((
        Tensor::f32(vec![GALLERY, EMBED_DIM], embeddings)?,
        Tensor::i32(vec![GALLERY], labels)?,
    ))
}

// ------------------------------------------------------------ the handlers --

/// Configuration for the video handlers.
#[derive(Debug, Clone)]
pub struct VideoConfig {
    /// Inter-frame mean-abs-diff threshold for "contains motion".
    pub motion_threshold: f32,
    /// Template-correlation threshold for "contains a face".
    pub face_threshold: f32,
    /// GoPs per camera per run.
    pub gops_per_camera: u64,
}

impl Default for VideoConfig {
    fn default() -> Self {
        VideoConfig { motion_threshold: 1e-3, face_threshold: 0.25, gops_per_camera: 1 }
    }
}

/// Register the six stage handlers on an executor. `gallery` is the
/// enrolled (embeddings, labels) pair, baked into the recognition closure
/// the way the paper bakes a pre-trained model into the function image.
pub fn register_handlers(
    executor: &NativeExecutor,
    engine: Arc<EngineService>,
    faas: Arc<EdgeFaaS>,
    cfg: VideoConfig,
    gallery: (Tensor, Tensor),
) {
    // ---- video-generator ----
    {
        let faas = Arc::clone(&faas);
        let cfg = cfg.clone();
        executor.register("video/video-generator", move |payload: &[u8]| {
            let env = parse_envelope(payload)?;
            let rid = env.resource;
            let mut urls = Vec::new();
            for g in 0..cfg.gops_per_camera {
                // Camera rid films identity rid%10; ~1 in 4 GoPs is static.
                let motion = (g + rid as u64) % 4 != 3;
                let gop = synth_gop(rid as u64, g, rid as usize, motion);
                let obj = format!("gop-{g}.bin");
                let url =
                    faas.put_object(APP, &bucket(rid), &obj, &pack_tensors(&[gop]))?;
                urls.push(url.to_string());
            }
            Ok(outputs_json(&urls))
        });
    }
    // ---- video-processing ----
    {
        let faas = Arc::clone(&faas);
        executor.register("video/video-processing", move |payload: &[u8]| {
            let env = parse_envelope(payload)?;
            let mut urls = Vec::new();
            for (i, input) in env.inputs.iter().enumerate() {
                let tensors = unpack_tensors(&faas.get_object_url(input)?)?;
                let gop = &tensors[0];
                anyhow::ensure!(
                    gop.shape == vec![GOP, FRAME_H, FRAME_W],
                    "bad GoP shape {:?}",
                    gop.shape
                );
                // FFmpeg stand-in: luma normalize to [0,1] (already the
                // range, so this is an explicit clamp + passthrough chunk).
                let data: Vec<f32> =
                    gop.as_f32()?.iter().map(|&v| v.clamp(0.0, 1.0)).collect();
                let out = Tensor::f32(gop.shape.clone(), data)?;
                let obj = format!("proc-{}-{i}.bin", env.resource);
                let url = faas.put_object(
                    APP,
                    &bucket(env.resource),
                    &obj,
                    &pack_tensors(&[out]),
                )?;
                urls.push(url.to_string());
            }
            Ok(outputs_json(&urls))
        });
    }
    // ---- motion-detection ----
    {
        let engine = Arc::clone(&engine);
        let faas = Arc::clone(&faas);
        let cfg = cfg.clone();
        executor.register("video/motion-detection", move |payload: &[u8]| {
            let env = parse_envelope(payload)?;
            let mut urls = Vec::new();
            for (i, input) in env.inputs.iter().enumerate() {
                let tensors = unpack_tensors(&faas.get_object_url(input)?)?;
                let gop = &tensors[0];
                let scores = engine.execute("motion_scores", &[gop.clone()])?;
                let scores = scores[0].as_f32()?;
                // "if a picture is detected with motion, all the following
                // pictures are considered to contain motion" — a GoP passes
                // if any inter-frame score clears the threshold.
                let has_motion = scores[1..].iter().any(|&s| s > cfg.motion_threshold);
                if !has_motion {
                    continue; // the stage is a filter
                }
                // Downstream stages take DETECT_BATCH frames: stride-sample.
                let data = gop.as_f32()?;
                let stride = GOP / DETECT_BATCH;
                let mut picked = Vec::with_capacity(DETECT_BATCH * FRAME_H * FRAME_W);
                for k in 0..DETECT_BATCH {
                    let f = k * stride;
                    picked.extend_from_slice(
                        &data[f * FRAME_H * FRAME_W..(f + 1) * FRAME_H * FRAME_W],
                    );
                }
                let out = Tensor::f32(vec![DETECT_BATCH, FRAME_H, FRAME_W], picked)?;
                let obj = format!("motion-{}-{i}.bin", env.resource);
                let url = faas.put_object(
                    APP,
                    &bucket(env.resource),
                    &obj,
                    &pack_tensors(&[out]),
                )?;
                urls.push(url.to_string());
            }
            Ok(outputs_json(&urls))
        });
    }
    // ---- face-detection ----
    {
        let engine = Arc::clone(&engine);
        let faas = Arc::clone(&faas);
        let cfg = cfg.clone();
        executor.register("video/face-detection", move |payload: &[u8]| {
            let env = parse_envelope(payload)?;
            let mut urls = Vec::new();
            for (i, input) in env.inputs.iter().enumerate() {
                let tensors = unpack_tensors(&faas.get_object_url(input)?)?;
                let frames = &tensors[0];
                let out = engine.execute("face_detect", &[frames.clone()])?;
                let scores = out[0].as_f32()?;
                let any_face = scores.iter().any(|&s| s > cfg.face_threshold);
                if !any_face {
                    continue; // filter again
                }
                let obj = format!("detect-{}-{i}.bin", env.resource);
                let url = faas.put_object(
                    APP,
                    &bucket(env.resource),
                    &obj,
                    // Frames + per-frame window indices travel together.
                    &pack_tensors(&[frames.clone(), out[1].clone(), out[0].clone()]),
                )?;
                urls.push(url.to_string());
            }
            Ok(outputs_json(&urls))
        });
    }
    // ---- face-extraction ----
    {
        let engine = Arc::clone(&engine);
        let faas = Arc::clone(&faas);
        executor.register("video/face-extraction", move |payload: &[u8]| {
            let env = parse_envelope(payload)?;
            let mut urls = Vec::new();
            for (i, input) in env.inputs.iter().enumerate() {
                let tensors = unpack_tensors(&faas.get_object_url(input)?)?;
                let (frames, windows) = (&tensors[0], &tensors[1]);
                let out = engine.execute("face_extract", &[frames.clone(), windows.clone()])?;
                let obj = format!("faces-{}-{i}.bin", env.resource);
                let url = faas.put_object(
                    APP,
                    &bucket(env.resource),
                    &obj,
                    &pack_tensors(&[out[0].clone()]),
                )?;
                urls.push(url.to_string());
            }
            Ok(outputs_json(&urls))
        });
    }
    // ---- face-recognition ----
    {
        let engine = Arc::clone(&engine);
        let faas = Arc::clone(&faas);
        executor.register("video/face-recognition", move |payload: &[u8]| {
            let env = parse_envelope(payload)?;
            let (gal_emb, gal_labels) = (&gallery.0, &gallery.1);
            let mut urls = Vec::new();
            for (i, input) in env.inputs.iter().enumerate() {
                let tensors = unpack_tensors(&faas.get_object_url(input)?)?;
                let patches = &tensors[0];
                let emb = engine.execute("face_embed", &[patches.clone()])?;
                let cls = engine.execute(
                    "knn_classify",
                    &[emb[0].clone(), gal_emb.clone(), gal_labels.clone()],
                )?;
                let obj = format!("identities-{}-{i}.bin", env.resource);
                let url = faas.put_object(
                    APP,
                    &bucket(env.resource),
                    &obj,
                    &pack_tensors(&[cls[0].clone(), cls[1].clone()]),
                )?;
                urls.push(url.to_string());
            }
            Ok(outputs_json(&urls))
        });
    }
}

/// Create the per-resource pipeline buckets.
pub fn create_buckets(faas: &EdgeFaaS, resources: &[ResourceId]) -> anyhow::Result<()> {
    for &rid in resources {
        faas.create_bucket(APP, &bucket(rid), Some(rid))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_gop_geometry_and_determinism() {
        let a = synth_gop(3, 0, 3, true);
        let b = synth_gop(3, 0, 3, true);
        assert_eq!(a, b);
        assert_eq!(a.shape, vec![GOP, FRAME_H, FRAME_W]);
        let c = synth_gop(3, 1, 3, true);
        assert_ne!(a, c, "different GoPs differ");
    }

    #[test]
    fn motion_flag_controls_frame_difference() {
        let moving = synth_gop(1, 0, 1, true);
        let still = synth_gop(1, 0, 1, false);
        let diff_of = |t: &Tensor| {
            let d = t.as_f32().unwrap();
            let f0 = &d[..FRAME_H * FRAME_W];
            let f12 = &d[12 * FRAME_H * FRAME_W..13 * FRAME_H * FRAME_W];
            f0.iter().zip(f12).map(|(a, b)| (a - b).abs()).sum::<f32>()
                / (FRAME_H * FRAME_W) as f32
        };
        assert!(diff_of(&moving) > 1e-3, "moving scene diff {}", diff_of(&moving));
        assert!(diff_of(&still) < 1e-6, "static scene diff {}", diff_of(&still));
    }

    #[test]
    fn frames_are_unit_range() {
        let gop = synth_gop(5, 2, 5, true);
        assert!(gop.as_f32().unwrap().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}
