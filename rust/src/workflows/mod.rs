//! The paper's two representative edge workflows (§4), implemented as real
//! EdgeFaaS functions whose compute runs through the PJRT artifacts.
//!
//! * [`video`] — the six-stage video-analytics pipeline (§4.1): synthetic
//!   camera streams, GoP chunking, Pallas motion detection, template-bank
//!   face detection, CNN embedding, k-NN recognition.
//! * [`fedlearn`] — the two-level federated-learning workflow (§4.2):
//!   LeNet-5 local training on per-device synthetic digit shards, edge-level
//!   FedAvg, cloud-level FedAvg.
//!
//! Handlers are registered into a [`crate::cluster::NativeExecutor`] under
//! image names (`video/motion-detection`, `fl/train`, ...) and speak the
//! invoker's URL-envelope protocol, so the full coordinator path — deploy,
//! schedule, invoke, chain, store — is exercised end to end.

pub mod common;
pub mod fedlearn;
pub mod video;
