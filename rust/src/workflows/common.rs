//! Shared plumbing for workflow function handlers.

use crate::runtime::Tensor;
use crate::util::json::Json;

/// Pack several tensors into one object payload:
/// `[count u32][len u32][tensor wire] x count`.
pub fn pack_tensors(tensors: &[Tensor]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        let wire = t.to_bytes();
        out.extend_from_slice(&(wire.len() as u32).to_le_bytes());
        out.extend_from_slice(&wire);
    }
    out
}

/// Inverse of [`pack_tensors`].
pub fn unpack_tensors(bytes: &[u8]) -> anyhow::Result<Vec<Tensor>> {
    if bytes.len() < 4 {
        anyhow::bail!("truncated tensor pack");
    }
    let count = u32::from_le_bytes(bytes[0..4].try_into()?) as usize;
    let mut off = 4;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        if off + 4 > bytes.len() {
            anyhow::bail!("truncated tensor pack header");
        }
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into()?) as usize;
        off += 4;
        if off + len > bytes.len() {
            anyhow::bail!("truncated tensor pack body");
        }
        out.push(Tensor::from_bytes(&bytes[off..off + len])?);
        off += len;
    }
    Ok(out)
}

/// Parse the invoker envelope common to all handlers.
pub struct Envelope {
    pub app: String,
    pub function: String,
    pub resource: u32,
    pub inputs: Vec<String>,
}

pub fn parse_envelope(payload: &[u8]) -> anyhow::Result<Envelope> {
    let v = crate::util::json::parse(std::str::from_utf8(payload)?)?;
    Ok(Envelope {
        app: v.req_str("app")?.to_string(),
        function: v.req_str("function")?.to_string(),
        resource: v.req_f64("resource")? as u32,
        inputs: v
            .get("inputs")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|u| u.as_str().map(String::from)).collect())
            .unwrap_or_default(),
    })
}

/// Build the handler response envelope.
pub fn outputs_json(urls: &[String]) -> Vec<u8> {
    let mut out = Json::obj();
    out.set("outputs", Json::Arr(urls.iter().map(|u| Json::Str(u.clone())).collect()));
    out.to_string().into_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_pack_roundtrip() {
        let ts = vec![
            Tensor::f32(vec![2, 2], vec![1., 2., 3., 4.]).unwrap(),
            Tensor::i32(vec![3], vec![7, 8, 9]).unwrap(),
            Tensor::scalar(0.5),
        ];
        let packed = pack_tensors(&ts);
        assert_eq!(unpack_tensors(&packed).unwrap(), ts);
    }

    #[test]
    fn empty_pack() {
        assert_eq!(unpack_tensors(&pack_tensors(&[])).unwrap(), Vec::<Tensor>::new());
        assert!(unpack_tensors(b"xx").is_err());
    }

    #[test]
    fn envelope_roundtrip() {
        let payload =
            br#"{"app":"fl","function":"train","resource":3,"inputs":["fl/b/3/o"]}"#;
        let e = parse_envelope(payload).unwrap();
        assert_eq!(e.app, "fl");
        assert_eq!(e.resource, 3);
        assert_eq!(e.inputs, vec!["fl/b/3/o"]);
        let out = outputs_json(&["a/b/1/c".to_string()]);
        let v = crate::util::json::parse(std::str::from_utf8(&out).unwrap()).unwrap();
        assert_eq!(v.get("outputs").unwrap().as_arr().unwrap().len(), 1);
    }
}
