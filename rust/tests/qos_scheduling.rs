//! Integration tests for the engine's QoS-aware run queue:
//!
//! * starvation/aging — 64 Batch runs plus one Realtime run under the
//!   VirtualClock: the Realtime run completes first, and every Batch run
//!   still completes (the aging guard keeps the class work-conserving);
//! * backpressure — deterministic `EngineError::Saturated` rejection at
//!   the configured bound, surfaced over REST as `429 Too Many Requests`
//!   with a `Retry-After` header;
//! * deadlines — a run whose deadline has passed fails as
//!   `deadline_exceeded` (REST) / `WaitError::DeadlineExceeded` (API)
//!   without executing its queued instances;
//! * wait semantics — a wait timeout is distinguishable from a run
//!   failure;
//! * determinism — identical firing orders and outputs for the same
//!   mixed-QoS submission sequence under RealClock and VirtualClock,
//!   batching on and off.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

use edgefaas::coordinator::functions::FunctionPackage;
use edgefaas::coordinator::gateway::EdgeFaasGateway;
use edgefaas::coordinator::{EngineError, EngineEvent, Priority, QoS, RunId, WaitError};
use edgefaas::simnet::{Clock, RealClock, VirtualClock};
use edgefaas::testbed::{paper_testbed, TestBed};
use edgefaas::util::http;
use edgefaas::util::json::Json;

const CHAIN_YAML: &str = "\
application: chain
entrypoint: gen
dag:
  - name: gen
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
  - name: sum
    dependencies: gen
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: 1
";

/// Configure the two-stage chain app (2 IoT generators -> 1 edge reducer).
fn configure_chain(bed: &TestBed) {
    let mut data = HashMap::new();
    data.insert("gen".to_string(), vec![bed.iot[0], bed.iot[1]]);
    bed.faas.configure_application(CHAIN_YAML, &data).unwrap();
    bed.faas.deploy_function("chain", "gen", &FunctionPackage { code: "img/gen".into() }).unwrap();
    bed.faas.deploy_function("chain", "sum", &FunctionPackage { code: "img/sum".into() }).unwrap();
}

/// A gate function handlers block on until the test opens it — makes queue
/// state at submission time deterministic under any clock.
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { open: Mutex::new(false), cv: Condvar::new() })
    }

    fn open(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

/// Zero-work handlers for both stages, blocking on `gate`.
fn register_gated_handlers(bed: &TestBed, gate: &Arc<Gate>) {
    for stage in ["gen", "sum"] {
        let gate = Arc::clone(gate);
        bed.executor.register(&format!("img/{stage}"), move |_: &[u8]| {
            gate.wait();
            Ok(br#"{"outputs":[]}"#.to_vec())
        });
    }
}

#[test]
fn realtime_finishes_first_and_batch_still_completes() {
    // 64 Batch runs + 1 Realtime run under the VirtualClock (the ISSUE's
    // starvation regression shape). A single worker makes the dispatch
    // sequence strictly the queue order; the gate holds execution until
    // every run is submitted.
    let bed = paper_testbed(Arc::new(VirtualClock::new()));
    let gate = Gate::new();
    register_gated_handlers(&bed, &gate);
    configure_chain(&bed);
    bed.faas.set_engine_limits(1, 8);

    let completions: Arc<Mutex<Vec<RunId>>> = Arc::new(Mutex::new(Vec::new()));
    {
        let completions = Arc::clone(&completions);
        bed.faas.on_engine_event(move |_, ev| {
            if let EngineEvent::RunCompleted { run, .. } = ev {
                completions.lock().unwrap().push(*run);
            }
        });
    }

    let batch_ids: Vec<RunId> = (0..64)
        .map(|_| {
            bed.faas
                .submit_workflow_qos("chain", &HashMap::new(), QoS::class(Priority::Batch))
                .unwrap()
        })
        .collect();
    let rt = bed
        .faas
        .submit_workflow_qos("chain", &HashMap::new(), QoS::class(Priority::Realtime))
        .unwrap();
    gate.open();

    bed.faas.wait_workflow(rt, 60.0).unwrap();
    for id in &batch_ids {
        bed.faas.wait_workflow(*id, 120.0).unwrap();
    }
    let order = completions.lock().unwrap();
    assert_eq!(order[0], rt, "the realtime run must complete before every batch run");
    assert_eq!(order.len(), 65, "all 64 batch runs still complete");
}

#[test]
fn saturated_rejection_is_deterministic_and_rest_returns_429() {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let gate = Gate::new();
    register_gated_handlers(&bed, &gate);
    configure_chain(&bed);
    bed.faas.set_backpressure(2, 4096);

    let server = EdgeFaasGateway::serve(Arc::clone(&bed.faas), 4).unwrap();
    let addr = server.addr();
    let submit = || {
        http::request(&addr, "POST", "/apps/chain/run?async=true&priority=batch", &[], &[])
            .unwrap()
    };
    let mut runs = Vec::new();
    for _ in 0..2 {
        let resp = submit();
        assert_eq!(resp.status, 202, "{}", resp.body_str().unwrap_or(""));
        runs.push(resp.json_body().unwrap().get("run").unwrap().as_u64().unwrap());
    }
    // The handlers are gated, so exactly 2 runs are pending: the third
    // batch submission is deterministically refused.
    for _ in 0..3 {
        let resp = submit();
        assert_eq!(resp.status, 429, "{}", resp.body_str().unwrap_or(""));
        let retry = resp.headers.get("retry-after").expect("Retry-After header present");
        assert!(retry.parse::<u64>().unwrap() >= 1, "whole-second hint: {retry}");
    }
    // The same rejection is typed on the native API.
    match bed.faas.submit_workflow_qos("chain", &HashMap::new(), QoS::class(Priority::Batch)) {
        Err(EngineError::Saturated { pending_runs, max_pending_runs, .. }) => {
            assert_eq!((pending_runs, max_pending_runs), (2, 2));
        }
        other => panic!("expected Saturated, got {other:?}"),
    }
    // Open the gate: the admitted runs drain and capacity returns.
    gate.open();
    for run in runs {
        let mut status = String::new();
        for _ in 0..400 {
            let resp = http::get(&addr, &format!("/runs/{run}")).unwrap();
            assert_eq!(resp.status, 200);
            status = resp.json_body().unwrap().req_str("status").unwrap().to_string();
            if status != "running" {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(status, "done");
    }
    let resp = submit();
    assert_eq!(resp.status, 202, "capacity restored after the backlog drained");
}

#[test]
fn missed_deadline_is_reported_as_deadline_exceeded_over_rest() {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    for stage in ["gen", "sum"] {
        bed.executor
            .register(&format!("img/{stage}"), |_: &[u8]| Ok(br#"{"outputs":[]}"#.to_vec()));
    }
    configure_chain(&bed);
    let server = EdgeFaasGateway::serve(Arc::clone(&bed.faas), 4).unwrap();
    let addr = server.addr();
    // A zero deadline is already past at first dispatch.
    let resp = http::request(
        &addr,
        "POST",
        "/apps/chain/run?async=true&priority=interactive&deadline_s=0",
        &[],
        &[],
    )
    .unwrap();
    assert_eq!(resp.status, 202, "{}", resp.body_str().unwrap_or(""));
    let run = resp.json_body().unwrap().get("run").unwrap().as_u64().unwrap();
    let mut last = Json::obj();
    for _ in 0..400 {
        let resp = http::get(&addr, &format!("/runs/{run}")).unwrap();
        assert_eq!(resp.status, 200);
        last = resp.json_body().unwrap();
        if last.req_str("status").unwrap() != "running" {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert_eq!(last.req_str("status").unwrap(), "deadline_exceeded");
    let qos = last.get("qos").expect("qos object reported");
    assert_eq!(qos.req_str("deadline_state").unwrap(), "missed");
}

#[test]
fn wait_timeout_is_not_a_run_failure() {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let gate = Gate::new();
    register_gated_handlers(&bed, &gate);
    configure_chain(&bed);
    let run = bed.faas.submit_workflow("chain", &HashMap::new()).unwrap();
    // The run is gated, so a short wait times out — a state distinct from
    // the run having failed: the same run can be waited on again and
    // completes fine.
    match bed.faas.wait_workflow(run, 0.05) {
        Err(WaitError::Timeout { run: r, .. }) => assert_eq!(r, run),
        other => panic!("expected Timeout, got {other:?}"),
    }
    gate.open();
    bed.faas.wait_workflow(run, 30.0).unwrap();
}

// ------------------------------------------------------- determinism ----

/// Tagged stub handlers: gen threads the run tag (from its entry-input
/// URL) into its output object; sum asserts all inputs share one tag and
/// writes `{tag}-sum-n{inputs}`. Outputs depend only on routing.
fn register_tagged_handlers(bed: &TestBed) {
    {
        let faas = Arc::clone(&bed.faas);
        bed.executor.register("img/gen", move |payload: &[u8]| {
            let v = edgefaas::util::json::parse(std::str::from_utf8(payload)?)?;
            let rid = v.get("resource").unwrap().as_u64().unwrap();
            let tag = v
                .get("inputs")
                .and_then(Json::as_arr)
                .and_then(|a| a.first())
                .and_then(Json::as_str)
                .unwrap_or("r?")
                .rsplit('/')
                .next()
                .unwrap_or("r?")
                .to_string();
            let obj = format!("{tag}-gen-{rid}.bin");
            let url = faas.put_object("chain", "work", &obj, tag.as_bytes())?;
            let mut out = Json::obj();
            out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
            Ok(out.to_string().into_bytes())
        });
    }
    {
        let faas = Arc::clone(&bed.faas);
        bed.executor.register("img/sum", move |payload: &[u8]| {
            let v = edgefaas::util::json::parse(std::str::from_utf8(payload)?)?;
            let inputs = v.get("inputs").and_then(Json::as_arr).unwrap_or(&[]).to_vec();
            let mut tags: Vec<String> = Vec::new();
            for u in &inputs {
                let data = faas.get_object_url(u.as_str().unwrap())?;
                tags.push(String::from_utf8_lossy(&data).to_string());
            }
            tags.sort();
            tags.dedup();
            anyhow::ensure!(tags.len() == 1, "inputs from mixed runs: {tags:?}");
            let obj = format!("{}-sum-n{}.bin", tags[0], inputs.len());
            let url = faas.put_object("chain", "work", &obj, tags[0].as_bytes())?;
            let mut out = Json::obj();
            out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
            Ok(out.to_string().into_bytes())
        });
    }
}

fn entry_for(tag: &str) -> HashMap<String, Vec<String>> {
    let mut m = HashMap::new();
    m.insert(
        "gen".to_string(),
        vec![format!("chain/work/0/{tag}"), format!("chain/work/1/{tag}")],
    );
    m
}

/// The mixed-QoS submission sequence: classes cycle Batch → Interactive →
/// Realtime, with a (far-future, never-missed) deadline on every third run.
fn mixed_sequence() -> Vec<(String, QoS)> {
    let classes = [Priority::Batch, Priority::Interactive, Priority::Realtime];
    (0..9)
        .map(|i| {
            let mut qos = QoS::class(classes[i % 3]);
            if i % 3 == 1 {
                qos = qos.with_deadline(1e6 + i as f64);
            }
            (format!("r{i}"), qos)
        })
        .collect()
}

/// Run the sequence on a fresh bed; returns per-run (firing_order, sum
/// output), in submission order.
fn run_mixed(clock: Arc<dyn Clock>, batching: bool) -> Vec<(Vec<String>, String)> {
    let bed = paper_testbed(clock);
    register_tagged_handlers(&bed);
    configure_chain(&bed);
    bed.faas.create_bucket("chain", "work", Some(bed.edges[0])).unwrap();
    bed.faas.set_batching(batching);
    // One admission slot per resource forces queuing, so the batched pass
    // actually forms multi-task batches.
    bed.faas.set_engine_limits(8, 1);
    let ids: Vec<RunId> = mixed_sequence()
        .into_iter()
        .map(|(tag, qos)| bed.faas.submit_workflow_qos("chain", &entry_for(&tag), qos).unwrap())
        .collect();
    ids.into_iter()
        .map(|id| {
            let r = bed.faas.wait_workflow(id, 120.0).unwrap();
            (r.firing_order.clone(), r.functions["sum"][0].outputs[0].clone())
        })
        .collect()
}

#[test]
fn mixed_qos_is_deterministic_across_clocks_and_batching() {
    let reference = run_mixed(Arc::new(RealClock::new()), true);
    for (i, (firing, out)) in reference.iter().enumerate() {
        assert_eq!(firing, &vec!["gen".to_string(), "sum".to_string()]);
        assert!(out.contains(&format!("r{i}-sum-n2")), "run r{i} contaminated: {out}");
    }
    let combos: Vec<(Arc<dyn Clock>, bool)> = vec![
        (Arc::new(RealClock::new()) as Arc<dyn Clock>, false),
        (Arc::new(VirtualClock::new()) as Arc<dyn Clock>, true),
        (Arc::new(VirtualClock::new()) as Arc<dyn Clock>, false),
    ];
    for (clock, batching) in combos {
        let got = run_mixed(clock, batching);
        assert_eq!(
            got, reference,
            "mixed-QoS outputs/firing orders must match the reference (batching={batching})"
        );
    }
}
