//! Integration tests for the event-driven execution engine:
//!
//! * determinism — the virtual-time and wall-clock engine paths must
//!   produce identical DAG firing orders and final outputs for the video
//!   and FL workflows (deterministic stub handlers stand in for the PJRT
//!   compute so the test runs without AOT artifacts);
//! * concurrency — at least 4 workflow runs submitted together must
//!   complete correctly, without cross-run contamination, under both
//!   clocks.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use edgefaas::coordinator::appconfig::{federated_learning_yaml, video_pipeline_yaml};
use edgefaas::coordinator::functions::FunctionPackage;
use edgefaas::coordinator::{ResourceId, WorkflowResult};
use edgefaas::simnet::{Clock, RealClock, VirtualClock};
use edgefaas::testbed::{paper_testbed, TestBed};
use edgefaas::util::json::Json;

/// Bucket all stub objects are written into (anchored to edge 0 so object
/// URLs are identical across testbeds).
const BUCKET: &str = "stub";

/// Register a deterministic stand-in handler for every stage: it writes one
/// object named after (stage, resource, inputs) whose content is the sorted
/// basenames of its inputs, so outputs depend only on routing — not timing.
fn register_stubs(bed: &TestBed, app: &'static str, stages: &[&str]) {
    for stage in stages {
        let faas = Arc::clone(&bed.faas);
        let stage_name = stage.to_string();
        bed.executor.register(&format!("img/stub-{stage}"), move |payload: &[u8]| {
            let v = edgefaas::util::json::parse(std::str::from_utf8(payload)?)?;
            let rid = v.get("resource").unwrap().as_u64().unwrap();
            let inputs: Vec<String> = v
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(|u| u.as_str().map(String::from))
                .collect();
            let mut names: Vec<String> = inputs
                .iter()
                .map(|u| u.rsplit('/').next().unwrap_or("?").to_string())
                .collect();
            names.sort();
            let obj = format!("{stage_name}-{rid}-n{}.bin", inputs.len());
            let url = faas.put_object(app, BUCKET, &obj, names.join(",").as_bytes())?;
            let mut out = Json::obj();
            out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
            Ok(out.to_string().into_bytes())
        });
    }
}

fn stub_packages(stages: &[&str]) -> HashMap<String, FunctionPackage> {
    stages
        .iter()
        .map(|s| (s.to_string(), FunctionPackage { code: format!("img/stub-{s}") }))
        .collect()
}

/// Run one stubbed workflow on a fresh paper testbed under `clock`.
fn run_stubbed(
    clock: Arc<dyn Clock>,
    yaml: &str,
    app: &'static str,
    stages: &[&str],
    data_fn: &str,
    data_of: impl Fn(&TestBed) -> Vec<ResourceId>,
) -> WorkflowResult {
    let bed = paper_testbed(clock);
    register_stubs(&bed, app, stages);
    bed.faas.create_bucket(app, BUCKET, Some(bed.edges[0])).unwrap();
    let mut data = HashMap::new();
    data.insert(data_fn.to_string(), data_of(&bed));
    bed.faas.configure_application(yaml, &data).unwrap();
    bed.faas.deploy_application(app, &stub_packages(stages)).unwrap();
    bed.faas.run_workflow(app, &HashMap::new()).unwrap()
}

/// Timing-independent projection of a result: function -> per-instance
/// (resource, outputs), in placement order.
fn normalized(result: &WorkflowResult) -> BTreeMap<String, Vec<(ResourceId, Vec<String>)>> {
    result
        .functions
        .iter()
        .map(|(k, v)| {
            (k.clone(), v.iter().map(|i| (i.resource, i.outputs.clone())).collect())
        })
        .collect()
}

// The canonical video stage list lives with the driver; FL has no such
// constant (fl_packages is keyed by these names).
use edgefaas::workflows::video::STAGES as VIDEO_STAGES;
const FL_STAGES: [&str; 3] = ["train", "firstaggregation", "secondaggregation"];

#[test]
fn virtual_and_wall_clock_paths_agree_for_the_video_workflow() {
    let wall = run_stubbed(
        Arc::new(RealClock::new()),
        video_pipeline_yaml(),
        "videopipeline",
        &VIDEO_STAGES,
        "video-generator",
        |bed| vec![bed.iot[0], bed.iot[1]],
    );
    let virt = run_stubbed(
        Arc::new(VirtualClock::new()),
        video_pipeline_yaml(),
        "videopipeline",
        &VIDEO_STAGES,
        "video-generator",
        |bed| vec![bed.iot[0], bed.iot[1]],
    );
    assert_eq!(wall.firing_order, virt.firing_order, "identical DAG firing orders");
    assert_eq!(wall.firing_order, VIDEO_STAGES);
    assert_eq!(normalized(&wall), normalized(&virt), "identical final outputs");
}

#[test]
fn virtual_and_wall_clock_paths_agree_for_the_fl_workflow() {
    let wall = run_stubbed(
        Arc::new(RealClock::new()),
        federated_learning_yaml(),
        "federatedlearning",
        &FL_STAGES,
        "train",
        |bed| bed.iot.clone(),
    );
    let virt = run_stubbed(
        Arc::new(VirtualClock::new()),
        federated_learning_yaml(),
        "federatedlearning",
        &FL_STAGES,
        "train",
        |bed| bed.iot.clone(),
    );
    assert_eq!(wall.firing_order, virt.firing_order, "identical DAG firing orders");
    assert_eq!(wall.firing_order, FL_STAGES);
    assert_eq!(normalized(&wall), normalized(&virt), "identical final outputs");
    // 8 trainers -> 2 edge aggregations of 4 -> 1 cloud aggregation of 2.
    assert_eq!(wall.functions["train"].len(), 8);
    for inst in &wall.functions["firstaggregation"] {
        assert!(inst.outputs[0].contains("-n4.bin"), "{:?}", inst.outputs);
    }
    assert!(wall.functions["secondaggregation"][0].outputs[0].contains("-n2.bin"));
}

/// Tag-threading FL stubs: the entry input carries a run tag; every stage
/// writes tag-stamped objects and asserts its inputs all came from the same
/// run. Detects cross-run contamination under concurrency.
fn register_tagged_fl(bed: &TestBed) {
    let app = "federatedlearning";
    for stage in FL_STAGES {
        let faas = Arc::clone(&bed.faas);
        let stage_name = stage.to_string();
        bed.executor.register(&format!("img/stub-{stage}"), move |payload: &[u8]| {
            let v = edgefaas::util::json::parse(std::str::from_utf8(payload)?)?;
            let rid = v.get("resource").unwrap().as_u64().unwrap();
            let inputs: Vec<String> = v
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(|u| u.as_str().map(String::from))
                .collect();
            // train: tag is the basename of the (pseudo) entry URL.
            // aggregators: tag is the content of each input object.
            let mut tags: Vec<String> = if stage_name == "train" {
                inputs.iter().map(|u| u.rsplit('/').next().unwrap_or("?").to_string()).collect()
            } else {
                let mut t = Vec::new();
                for u in &inputs {
                    let data = faas.get_object_url(u)?;
                    t.push(String::from_utf8_lossy(&data).to_string());
                }
                t
            };
            tags.sort();
            tags.dedup();
            anyhow::ensure!(tags.len() == 1, "{stage_name} mixed runs: {tags:?}");
            let tag = &tags[0];
            let obj = format!("{tag}-{stage_name}-{rid}-n{}.bin", inputs.len());
            let url = faas.put_object(app, BUCKET, &obj, tag.as_bytes())?;
            let mut out = Json::obj();
            out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
            Ok(out.to_string().into_bytes())
        });
    }
}

#[test]
fn four_plus_concurrent_runs_complete_under_both_clocks() {
    for clock in [
        Arc::new(RealClock::new()) as Arc<dyn Clock>,
        Arc::new(VirtualClock::new()) as Arc<dyn Clock>,
    ] {
        let bed = paper_testbed(clock);
        register_tagged_fl(&bed);
        bed.faas.create_bucket("federatedlearning", BUCKET, Some(bed.edges[0])).unwrap();
        let mut data = HashMap::new();
        data.insert("train".to_string(), bed.iot.clone());
        bed.faas.configure_application(federated_learning_yaml(), &data).unwrap();
        bed.faas
            .deploy_application("federatedlearning", &stub_packages(&FL_STAGES))
            .unwrap();

        // Submit 5 runs before awaiting any: they interleave on the shared
        // engine, each tagged through its entry inputs.
        let runs: Vec<(String, edgefaas::coordinator::RunId)> = (0..5)
            .map(|i| {
                let tag = format!("r{i}");
                // One pseudo entry URL per Pi, routed to that Pi's trainer.
                let urls: Vec<String> = bed
                    .iot
                    .iter()
                    .map(|&rid| format!("federatedlearning/{BUCKET}/{rid}/{tag}"))
                    .collect();
                let mut entry = HashMap::new();
                entry.insert("train".to_string(), urls);
                let id = bed.faas.submit_workflow("federatedlearning", &entry).unwrap();
                (tag, id)
            })
            .collect();
        for (tag, id) in runs {
            let result = bed.faas.wait_workflow(id, 60.0).unwrap();
            assert_eq!(result.firing_order, FL_STAGES, "run {tag}");
            assert_eq!(result.functions["train"].len(), 8, "run {tag}");
            let final_out = &result.functions["secondaggregation"][0].outputs[0];
            assert!(
                final_out.contains(&format!("{tag}-secondaggregation")),
                "run {tag} final output came from another run: {final_out}"
            );
            assert!(final_out.contains("-n2.bin"), "{final_out}");
        }
    }
}
