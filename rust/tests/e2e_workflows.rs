//! End-to-end integration: both paper workflows through the full stack —
//! coordinator scheduling, per-resource FaaS backends, object stores, and
//! the PJRT-executed AOT artifacts. Python never runs here.

use std::collections::HashMap;
use std::sync::Arc;

use edgefaas::coordinator::appconfig::{federated_learning_yaml, video_pipeline_yaml};
use edgefaas::runtime::{EngineService, Tensor};
use edgefaas::simnet::RealClock;
use edgefaas::testbed::{artifacts_dir, paper_testbed};
use edgefaas::workflows::{common, fedlearn, video};

fn engine() -> Option<Arc<EngineService>> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(EngineService::start(dir).unwrap()))
}

#[test]
fn federated_learning_end_to_end() {
    let Some(engine) = engine() else { return };
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let faas = Arc::clone(&bed.faas);
    let cfg = fedlearn::FlConfig { local_steps: 2, batch: 32, lr: 0.2, shard_size: 64 };

    fedlearn::seed_shards(&faas, &bed.iot, &cfg, 42).unwrap();
    fedlearn::create_model_buckets(&faas, &bed.all_resources()).unwrap();
    fedlearn::register_handlers(&bed.executor, Arc::clone(&engine), Arc::clone(&faas), cfg);

    // Configure + deploy per the paper's YAML (source code 2).
    let mut data = HashMap::new();
    data.insert("train".to_string(), bed.iot.clone());
    let plan = faas.configure_application(federated_learning_yaml(), &data).unwrap();
    assert_eq!(plan["train"].len(), 8);
    assert_eq!(plan["firstaggregation"], bed.edges);
    assert_eq!(plan["secondaggregation"], vec![bed.cloud]);
    faas.deploy_application(fedlearn::APP, &fedlearn::fl_packages()).unwrap();

    // Two federated rounds; the global model's eval accuracy must improve.
    let mut global = fedlearn::lenet_init(7);
    let acc_before = fedlearn::evaluate(&engine, &global, 999, 2).unwrap();
    for round in 0..2 {
        // Distribute the global model to every worker's bucket (the
        // aggregator "sends the shared model back to each of the workers").
        let urls = fedlearn::distribute_global(&faas, &bed.iot, round, &global).unwrap();
        let mut entry = HashMap::new();
        entry.insert("train".to_string(), urls);
        let result = faas.run_workflow(fedlearn::APP, &entry).unwrap();
        let final_url = &result.functions["secondaggregation"][0].outputs[0];
        global = Tensor::from_bytes(&faas.get_object_url(final_url).unwrap()).unwrap();
        assert_eq!(global.shape, vec![fedlearn::LENET_PARAMS]);
    }
    let acc_after = fedlearn::evaluate(&engine, &global, 999, 2).unwrap();
    assert!(
        acc_after > acc_before + 0.1,
        "federated training must help: {acc_before:.3} -> {acc_after:.3}"
    );
}

#[test]
fn video_pipeline_end_to_end() {
    let Some(engine) = engine() else { return };
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let faas = Arc::clone(&bed.faas);

    video::create_buckets(&faas, &bed.all_resources()).unwrap();
    let gallery = video::enroll_gallery(&engine, 5).unwrap();
    let cfg = video::VideoConfig::default();
    video::register_handlers(
        &bed.executor,
        Arc::clone(&engine),
        Arc::clone(&faas),
        cfg,
        gallery,
    );

    // Use the first set's cameras only to keep CI time modest.
    let cameras = vec![bed.iot[0], bed.iot[1]];
    let mut data = HashMap::new();
    data.insert("video-generator".to_string(), cameras.clone());
    let plan = faas.configure_application(video_pipeline_yaml(), &data).unwrap();
    assert_eq!(plan["video-generator"], cameras, "cameras co-locate with data");
    assert_eq!(plan["video-processing"], vec![bed.edges[0]], "set-1 edge");
    assert_eq!(plan["face-extraction"], vec![bed.cloud]);

    faas.deploy_application(video::APP, &video::video_packages()).unwrap();

    let result = faas.run_workflow(video::APP, &HashMap::new()).unwrap();
    assert_eq!(result.firing_order, video::STAGES, "engine fires the chain in order");

    // The pipeline must produce identity outputs on the cloud.
    let rec = &result.functions["face-recognition"];
    assert_eq!(rec.len(), 1);
    assert_eq!(rec[0].resource, bed.cloud);
    assert!(!rec[0].outputs.is_empty(), "no identities produced");
    // Decode one identities object: labels in 0..10 with finite distances.
    let raw = faas.get_object_url(&rec[0].outputs[0]).unwrap();
    let tensors = common::unpack_tensors(&raw).unwrap();
    let labels = tensors[0].as_i32().unwrap();
    assert!(!labels.is_empty());
    assert!(labels.iter().all(|&l| (0..10).contains(&l)), "labels: {labels:?}");
    let dists = tensors[1].as_f32().unwrap();
    assert!(dists.iter().all(|d| d.is_finite()));
}

#[test]
fn coordinator_recovers_mappings_from_backup() {
    // Crash-recovery: a coordinator rebuilt over the same DurableKv sees
    // the same candidate/bucket mappings (the paper's DynamoDB story).
    let dir = std::env::temp_dir().join(format!("edgefaas-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let kv_path = dir.join("mappings.jsonl");
    {
        let kv = edgefaas::backup::DurableKv::open(&kv_path).unwrap();
        kv.put("candidate_resource", "app.fn", edgefaas::util::json::Json::Num(3.0)).unwrap();
        kv.put("bucket_map", "app.data", edgefaas::util::json::Json::Num(1.0)).unwrap();
    }
    let kv = edgefaas::backup::DurableKv::open(&kv_path).unwrap();
    assert_eq!(
        kv.get("candidate_resource", "app.fn"),
        Some(edgefaas::util::json::Json::Num(3.0))
    );
    assert_eq!(kv.get("bucket_map", "app.data"), Some(edgefaas::util::json::Json::Num(1.0)));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn rest_control_plane_end_to_end() {
    // The unified gateway + per-resource REST path: configure and exercise
    // storage verbs through loopback HTTP only.
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let server =
        edgefaas::coordinator::gateway::EdgeFaasGateway::serve(Arc::clone(&bed.faas), 4).unwrap();
    let addr = server.addr();
    let anchors: String = bed.iot.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(",");
    let resp = edgefaas::util::http::request(
        &addr,
        "POST",
        &format!("/apps?data_train={anchors}"),
        &[],
        federated_learning_yaml().as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 201, "{}", resp.body_str().unwrap_or(""));
    // Storage through the gateway.
    let resp = edgefaas::util::http::request(
        &addr,
        "PUT",
        &format!("/apps/federatedlearning/buckets/shared?locality={}", bed.cloud),
        &[],
        &[],
    )
    .unwrap();
    assert_eq!(resp.status, 201);
    let resp = edgefaas::util::http::request(
        &addr,
        "PUT",
        "/apps/federatedlearning/objects/shared/model.bin",
        &[],
        &fedlearn::lenet_init(0).to_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 201);
    let url = resp.json_body().unwrap().req_str("url").unwrap().to_string();
    let resp = edgefaas::util::http::get(
        &addr,
        &format!("/objects?url={}", edgefaas::util::http::url_encode(&url)),
    )
    .unwrap();
    let model = Tensor::from_bytes(&resp.body).unwrap();
    assert_eq!(model.shape, vec![fedlearn::LENET_PARAMS]);
}
