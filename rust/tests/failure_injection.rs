//! Failure injection: the coordinator's behaviour when resources misbehave
//! — partial deploy failures, invocation errors, unreachable monitoring,
//! capacity exhaustion mid-workflow. The paper specifies several of these
//! behaviours explicitly (§3.2.1: failed resource IDs are returned and
//! removed from the candidate mapping).
//!
//! The second half is the liveness-plane chaos suite: 16-resource beds on
//! virtual time where nodes are killed, flapped, or half-killed mid-run,
//! asserting detection (`Alive -> Suspect -> Dead`), queued-work drain,
//! at-most-once retry via attempt-id dedup, quarantine re-admission, and
//! that no `wait_workflow` caller ever hangs.
//!
//! The final section swaps the in-process handles for real sockets: every
//! resource is an HTTP triplet (FaaS gateway, Prometheus exporter, object
//! store) behind an [`HttpHandle`], and partitions are injected at the
//! wire by the seeded fault plane (`util::faults`) — symmetric and
//! asymmetric black holes, plus probabilistic resets whose outcomes must
//! be identical per fault seed across engine shard counts.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use edgefaas::backup::DurableKv;
use edgefaas::cluster::faas::{Executor, FaasBackend, NativeExecutor};
use edgefaas::cluster::gateway::FaasGateway;
use edgefaas::cluster::spec::ResourceSpec;
use edgefaas::coordinator::engine::{EngineEvent, ResourceBusy, RunId, WaitError};
use edgefaas::coordinator::functions::FunctionPackage;
use edgefaas::coordinator::handle::{HttpHandle, LocalHandle, ResourceHandle, VerbBudgets};
use edgefaas::coordinator::resource::{EdgeFaaS, ResourceId};
use edgefaas::monitor::metrics::{MetricsRegistry, ResourceUsage};
use edgefaas::monitor::scrape::{scrape_with, MetricsGateway};
use edgefaas::monitor::LeaseState;
use edgefaas::objstore::gateway::StoreGateway;
use edgefaas::objstore::ObjectStore;
use edgefaas::simnet::topology::mbps;
use edgefaas::simnet::{Clock, RealClock, Tier, Topology, VirtualClock};
use edgefaas::testbed::paper_testbed;
use edgefaas::util::bytes::Bytes;
use edgefaas::util::faults::{self, FaultKind, FaultRule};
use edgefaas::util::http::{Handler, RequestOptions, Server};
use edgefaas::util::json::Json;

/// A handle wrapper that can be told to fail specific verbs.
struct FlakyHandle {
    inner: Arc<dyn ResourceHandle>,
    fail_deploy: AtomicBool,
    fail_invoke: AtomicBool,
    fail_usage: AtomicBool,
    invokes: AtomicUsize,
}

impl FlakyHandle {
    fn wrap(inner: Arc<dyn ResourceHandle>) -> Arc<FlakyHandle> {
        Arc::new(FlakyHandle {
            inner,
            fail_deploy: AtomicBool::new(false),
            fail_invoke: AtomicBool::new(false),
            fail_usage: AtomicBool::new(false),
            invokes: AtomicUsize::new(0),
        })
    }
}

impl ResourceHandle for FlakyHandle {
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        if self.fail_deploy.load(Ordering::SeqCst) {
            anyhow::bail!("injected deploy failure");
        }
        self.inner.deploy(name, image, memory, gpus, labels)
    }

    fn remove(&self, name: &str) -> anyhow::Result<()> {
        self.inner.remove(name)
    }

    fn invoke(&self, name: &str, payload: &Bytes) -> anyhow::Result<(Bytes, f64)> {
        self.invokes.fetch_add(1, Ordering::SeqCst);
        if self.fail_invoke.load(Ordering::SeqCst) {
            anyhow::bail!("injected invoke failure");
        }
        self.inner.invoke(name, payload)
    }

    fn list(&self) -> anyhow::Result<Vec<String>> {
        self.inner.list()
    }

    fn describe(&self, name: &str) -> anyhow::Result<Json> {
        self.inner.describe(name)
    }

    fn usage(&self) -> anyhow::Result<ResourceUsage> {
        if self.fail_usage.load(Ordering::SeqCst) {
            anyhow::bail!("injected scrape failure");
        }
        self.inner.usage()
    }

    fn make_bucket(&self, b: &str) -> anyhow::Result<()> {
        self.inner.make_bucket(b)
    }
    fn remove_bucket(&self, b: &str) -> anyhow::Result<()> {
        self.inner.remove_bucket(b)
    }
    fn put_object(&self, b: &str, o: &str, d: Bytes) -> anyhow::Result<()> {
        self.inner.put_object(b, o, d)
    }
    fn get_object(&self, b: &str, o: &str) -> anyhow::Result<Bytes> {
        self.inner.get_object(b, o)
    }
    fn remove_object(&self, b: &str, o: &str) -> anyhow::Result<()> {
        self.inner.remove_object(b, o)
    }
    fn list_objects(&self, b: &str) -> anyhow::Result<Vec<String>> {
        self.inner.list_objects(b)
    }
    fn stored_bytes(&self) -> anyhow::Result<u64> {
        self.inner.stored_bytes()
    }
}

/// Testbed where one IoT resource is wrapped in a FlakyHandle.
fn flaky_bed() -> (edgefaas::testbed::TestBed, Arc<FlakyHandle>, u32) {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    // Re-register pi 7 behind a flaky wrapper (unregister requires it to be
    // clean, which a fresh testbed satisfies).
    let victim = bed.iot[7];
    let reg = bed.faas.resource(victim).unwrap();
    let flaky = FlakyHandle::wrap(Arc::clone(&reg.handle));
    let (spec, node) = (reg.spec.clone(), reg.net_node);
    bed.faas.unregister(victim).unwrap();
    let new_id = bed
        .faas
        .register(spec, Arc::clone(&flaky) as Arc<dyn ResourceHandle>, node)
        .unwrap();
    assert_eq!(new_id, victim, "id reuse keeps the testbed layout");
    (bed, flaky, victim)
}

#[test]
fn partial_deploy_failure_prunes_candidates_per_paper() {
    let (bed, flaky, victim) = flaky_bed();
    bed.executor.register("img/x", |p: &[u8]| Ok(p.to_vec()));
    let yaml = edgefaas::coordinator::appconfig::federated_learning_yaml();
    let mut data = HashMap::new();
    data.insert("train".to_string(), bed.iot.clone());
    bed.faas.configure_application(yaml, &data).unwrap();
    flaky.fail_deploy.store(true, Ordering::SeqCst);
    // "If the function fails to be created on some resources,
    // create_function() returns error and the failed resource IDs...
    // removed from the candidate resource mapping."
    let err = bed
        .faas
        .deploy_function("federatedlearning", "train", &FunctionPackage { code: "img/x".into() })
        .unwrap_err()
        .to_string();
    assert!(err.contains(&victim.to_string()), "error names the failed id: {err}");
    let remaining = bed.faas.candidates_of("federatedlearning", "train").unwrap();
    assert_eq!(remaining.len(), 7);
    assert!(!remaining.contains(&victim), "failed id pruned from mapping");
    // The other 7 deployments are live and invocable.
    let results = bed.faas.invoke("federatedlearning", "train", &Json::obj(), false).unwrap();
    assert_eq!(results.len(), 7);
}

#[test]
fn invoke_failure_propagates_with_resource_id() {
    let (bed, flaky, victim) = flaky_bed();
    bed.executor.register("img/x", |p: &[u8]| Ok(p.to_vec()));
    let yaml = edgefaas::coordinator::appconfig::federated_learning_yaml();
    let mut data = HashMap::new();
    data.insert("train".to_string(), bed.iot.clone());
    bed.faas.configure_application(yaml, &data).unwrap();
    bed.faas
        .deploy_function("federatedlearning", "train", &FunctionPackage { code: "img/x".into() })
        .unwrap();
    flaky.fail_invoke.store(true, Ordering::SeqCst);
    let err =
        bed.faas.invoke("federatedlearning", "train", &Json::obj(), false).unwrap_err().to_string();
    assert!(err.contains("injected invoke failure"), "{err}");
    let _ = victim;
}

#[test]
fn unreachable_monitoring_filters_resource_out() {
    let (bed, flaky, victim) = flaky_bed();
    flaky.fail_usage.store(true, Ordering::SeqCst);
    // Schedule an IoT function over all Pis: the scrape-failing one must be
    // dropped by phase 1 (fail-safe: no metrics, no placement).
    let yaml = edgefaas::coordinator::appconfig::federated_learning_yaml();
    let mut data = HashMap::new();
    data.insert("train".to_string(), bed.iot.clone());
    let plan = bed.faas.configure_application(yaml, &data).unwrap();
    assert_eq!(plan["train"].len(), 7);
    assert!(!plan["train"].contains(&victim));
}

#[test]
fn workflow_fails_cleanly_when_a_stage_errors() {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let faas = Arc::clone(&bed.faas);
    bed.executor.register("img/ok", |_: &[u8]| {
        Ok(br#"{"outputs":[]}"#.to_vec())
    });
    bed.executor.register("img/boom", |_: &[u8]| anyhow::bail!("stage exploded"));
    let yaml = "\
application: fragile
entrypoint: a
dag:
  - name: a
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
  - name: b
    dependencies: a
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: 1
";
    let mut data = HashMap::new();
    data.insert("a".to_string(), vec![bed.iot[0]]);
    faas.configure_application(yaml, &data).unwrap();
    faas.deploy_function("fragile", "a", &FunctionPackage { code: "img/ok".into() }).unwrap();
    faas.deploy_function("fragile", "b", &FunctionPackage { code: "img/boom".into() }).unwrap();
    let err = faas.run_workflow("fragile", &HashMap::new()).unwrap_err().to_string();
    assert!(err.contains("stage exploded"), "{err}");
}

#[test]
fn capacity_exhaustion_surfaces_as_invocation_error() {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    // A function whose sandbox takes 3 GB on a 4 GB Pi: the second
    // *concurrent* admission must fail (paper: resources are finite).
    let reg = bed.faas.resource(bed.iot[0]).unwrap();
    bed.executor.register("img/hold", |_: &[u8]| {
        std::thread::sleep(std::time::Duration::from_millis(300));
        Ok(vec![])
    });
    reg.handle.deploy("big", "img/hold", 3 << 30, 0, &[]).unwrap();
    let h = Arc::clone(&reg.handle);
    let t = std::thread::spawn(move || h.invoke("big", &Bytes::new()));
    std::thread::sleep(std::time::Duration::from_millis(50));
    let second = reg.handle.invoke("big", &Bytes::new());
    assert!(second.is_err(), "no memory for a second sandbox");
    assert!(t.join().unwrap().is_ok(), "first invocation unaffected");
    // After the first completes, capacity is back (warm sandbox reused).
    let third = reg.handle.invoke("big", &Bytes::new());
    assert!(third.is_ok());
}

#[test]
fn store_full_surfaces_through_virtual_storage() {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let faas = Arc::clone(&bed.faas);
    faas.create_bucket("fillme", "data", Some(bed.iot[0])).unwrap();
    // A Pi's store is 64 GB; don't fill it — use a tiny custom resource
    // instead: emulate by writing one object larger than free capacity.
    let huge = vec![0u8; 1 << 20];
    // 64 GB / 1 MiB = 65536 objects — too slow; instead assert the error
    // path via the store's own capacity check with an oversized single
    // object on a tiny ObjectStore.
    let small = edgefaas::objstore::ObjectStore::new(512, "ak", "sk");
    small.make_bucket("data").unwrap();
    let err = small.put_object("data", "big", huge.into()).unwrap_err();
    assert!(matches!(err, edgefaas::objstore::store::StoreError::Full { .. }));
}

// ==================== liveness-plane chaos suite =========================

/// A handle wrapper for chaos runs. `kill` makes every coordinator-facing
/// verb fail the way a crashed node would (connection refused); `revive`
/// brings it back. `lose_next_reply` executes the next batch for real but
/// drops its reply — the half-dead case the attempt-id dedup exists for —
/// and `fail_usage` fails only the monitoring scrape (the engine's
/// infrastructure-death probe) while invocations still go through.
struct KillableHandle {
    inner: Arc<dyn ResourceHandle>,
    dead: AtomicBool,
    fail_usage: AtomicBool,
    lose_next_reply: AtomicBool,
}

impl KillableHandle {
    fn wrap(inner: Arc<dyn ResourceHandle>) -> Arc<KillableHandle> {
        Arc::new(KillableHandle {
            inner,
            dead: AtomicBool::new(false),
            fail_usage: AtomicBool::new(false),
            lose_next_reply: AtomicBool::new(false),
        })
    }

    fn kill(&self) {
        self.dead.store(true, Ordering::SeqCst);
    }

    fn revive(&self) {
        self.dead.store(false, Ordering::SeqCst);
        self.fail_usage.store(false, Ordering::SeqCst);
    }

    fn check(&self) -> anyhow::Result<()> {
        if self.dead.load(Ordering::SeqCst) {
            anyhow::bail!("connection refused (node down)");
        }
        Ok(())
    }
}

impl ResourceHandle for KillableHandle {
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        self.check()?;
        self.inner.deploy(name, image, memory, gpus, labels)
    }

    fn remove(&self, name: &str) -> anyhow::Result<()> {
        self.check()?;
        self.inner.remove(name)
    }

    fn invoke(&self, name: &str, payload: &Bytes) -> anyhow::Result<(Bytes, f64)> {
        self.check()?;
        self.inner.invoke(name, payload)
    }

    fn invoke_batch(
        &self,
        calls: &[edgefaas::cluster::faas::BatchCall],
    ) -> Vec<anyhow::Result<(Bytes, f64)>> {
        if self.dead.load(Ordering::SeqCst) {
            return calls
                .iter()
                .map(|_| Err(anyhow::anyhow!("connection refused (node down)")))
                .collect();
        }
        if self.lose_next_reply.swap(false, Ordering::SeqCst) {
            // The node executes the batch (its backend records the attempt
            // ids) but the reply never reaches the coordinator.
            let _ = self.inner.invoke_batch(calls);
            return calls.iter().map(|_| Err(anyhow::anyhow!("reply lost"))).collect();
        }
        self.inner.invoke_batch(calls)
    }

    fn list(&self) -> anyhow::Result<Vec<String>> {
        self.check()?;
        self.inner.list()
    }

    fn describe(&self, name: &str) -> anyhow::Result<Json> {
        self.inner.describe(name)
    }

    fn usage(&self) -> anyhow::Result<ResourceUsage> {
        self.check()?;
        if self.fail_usage.load(Ordering::SeqCst) {
            anyhow::bail!("scrape timed out");
        }
        self.inner.usage()
    }

    fn make_bucket(&self, b: &str) -> anyhow::Result<()> {
        self.inner.make_bucket(b)
    }
    fn remove_bucket(&self, b: &str) -> anyhow::Result<()> {
        self.inner.remove_bucket(b)
    }
    fn put_object(&self, b: &str, o: &str, d: Bytes) -> anyhow::Result<()> {
        self.inner.put_object(b, o, d)
    }
    fn get_object(&self, b: &str, o: &str) -> anyhow::Result<Bytes> {
        self.inner.get_object(b, o)
    }
    fn remove_object(&self, b: &str, o: &str) -> anyhow::Result<()> {
        self.inner.remove_object(b, o)
    }
    fn list_objects(&self, b: &str) -> anyhow::Result<Vec<String>> {
        self.inner.list_objects(b)
    }
    fn stored_bytes(&self) -> anyhow::Result<u64> {
        self.inner.stored_bytes()
    }
}

/// A gate handler instances can be parked on: `entered` counts arrivals,
/// `release` lets them all through. Real OS blocking, so it composes with
/// `VirtualClock` (a parked handler is not a virtual sleeper).
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
    entered: AtomicUsize,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { open: Mutex::new(false), cv: Condvar::new(), entered: AtomicUsize::new(0) })
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn enter_and_wait(&self) {
        self.entered.fetch_add(1, Ordering::SeqCst);
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

struct ChaosBed {
    faas: Arc<EdgeFaaS>,
    executor: Arc<NativeExecutor>,
    /// One killable handle per resource, same order as `resources`.
    handles: Vec<Arc<KillableHandle>>,
    resources: Vec<ResourceId>,
}

/// `n` IoT resources hanging off one edge hub, every handle killable, the
/// whole bed on virtual time — chaos runs are deterministic and sweep
/// counts, not wall clocks, drive detection.
fn chaos_bed(n: usize) -> ChaosBed {
    let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
    let mut topo = Topology::new();
    let hub = topo.add_node("hub", Tier::Edge);
    let nodes: Vec<usize> = (0..n)
        .map(|i| {
            let node = topo.add_node(format!("chaos-{i}"), Tier::Iot);
            topo.add_link(node, hub, 0.001, mbps(100.0));
            node
        })
        .collect();
    let executor = Arc::new(NativeExecutor::new());
    let faas =
        Arc::new(EdgeFaaS::with_parts(topo, DurableKv::ephemeral(), Arc::clone(&clock)));
    let mut handles = Vec::new();
    let mut resources = Vec::new();
    for (i, &node) in nodes.iter().enumerate() {
        let spec = ResourceSpec::paper_iot(&format!("chaos{i}:8080"));
        let backend = Arc::new(FaasBackend::new(
            spec.clone(),
            Arc::clone(&executor) as Arc<dyn Executor>,
            Arc::clone(&clock),
        ));
        let store = Arc::new(ObjectStore::new(
            spec.storage * spec.nodes as u64,
            &spec.minio_access_key,
            &spec.minio_secret_key,
        ));
        let inner = Arc::new(LocalHandle::new(backend, store)) as Arc<dyn ResourceHandle>;
        let killable = KillableHandle::wrap(inner);
        let id = faas
            .register(spec, Arc::clone(&killable) as Arc<dyn ResourceHandle>, node)
            .unwrap();
        handles.push(killable);
        resources.push(id);
    }
    ChaosBed { faas, executor, handles, resources }
}

/// Configure + deploy a single-function app fanning one instance onto each
/// anchor resource. Returns the handler-execution counter. Instances on
/// `gate_on.0` park on the gate until released.
fn fanout_app(
    bed: &ChaosBed,
    app: &str,
    anchors: &[ResourceId],
    gate_on: Option<(ResourceId, Arc<Gate>)>,
) -> Arc<AtomicUsize> {
    let executions = Arc::new(AtomicUsize::new(0));
    let img = format!("img/{app}");
    {
        let executions = Arc::clone(&executions);
        bed.executor.register(&img, move |payload: &[u8]| {
            executions.fetch_add(1, Ordering::SeqCst);
            if let Some((gated, gate)) = &gate_on {
                let v = edgefaas::util::json::parse(std::str::from_utf8(payload)?)?;
                let rid = v.get("resource").and_then(Json::as_u64).unwrap_or(u64::MAX);
                if rid == *gated as u64 {
                    gate.enter_and_wait();
                }
            }
            Ok(br#"{"outputs":[]}"#.to_vec())
        });
    }
    let yaml = format!(
        "\
application: {app}
entrypoint: f
dag:
  - name: f
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
"
    );
    let mut data = HashMap::new();
    data.insert("f".to_string(), anchors.to_vec());
    bed.faas.configure_application(&yaml, &data).unwrap();
    bed.faas.deploy_function(app, "f", &FunctionPackage { code: img }).unwrap();
    executions
}

fn lease_state(bed: &ChaosBed, id: ResourceId) -> LeaseState {
    bed.faas.monitor_snapshot().lease_of(id).expect("lease exists after a sweep").state
}

#[test]
fn killed_resource_is_detected_drained_and_runs_complete() {
    let bed = chaos_bed(16);
    let victim = bed.resources[3];
    let gate = Gate::new();
    fanout_app(&bed, "chaos", &bed.resources, Some((victim, Arc::clone(&gate))));
    // One admission slot per resource: the victim's first instance blocks
    // in the gate, later runs' victim instances queue behind it.
    bed.faas.set_engine_limits(32, 1);
    let dead_events = Arc::new(Mutex::new(Vec::new()));
    {
        let dead_events = Arc::clone(&dead_events);
        bed.faas.on_engine_event(move |_, ev| {
            if let EngineEvent::ResourceDead { resource, queued_moved, queued_failed } = ev {
                dead_events.lock().unwrap().push((*resource, *queued_moved, *queued_failed));
            }
        });
    }
    assert_eq!(bed.faas.refresh_monitor_snapshot(), 1);
    assert_eq!(lease_state(&bed, victim), LeaseState::Alive);
    let runs: Vec<RunId> = (0..3)
        .map(|_| bed.faas.submit_workflow("chaos", &HashMap::new()).unwrap())
        .collect();
    // Kill only once the victim's first instance is actually executing,
    // and give the workers a moment to park the later ones at admission.
    while gate.entered.load(Ordering::SeqCst) == 0 {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    std::thread::sleep(std::time::Duration::from_millis(100));
    bed.handles[3].kill();
    // Time-to-detect is sweep-counted: 1 miss = Suspect (still
    // schedulable), dead_after = 3 misses = Dead.
    bed.faas.refresh_monitor_snapshot();
    assert_eq!(lease_state(&bed, victim), LeaseState::Suspect);
    assert!(lease_state(&bed, victim).schedulable());
    assert!(dead_events.lock().unwrap().is_empty(), "Suspect must not drain");
    bed.faas.refresh_monitor_snapshot();
    bed.faas.refresh_monitor_snapshot();
    assert_eq!(lease_state(&bed, victim), LeaseState::Dead);
    let drained = dead_events.lock().unwrap().clone();
    assert_eq!(drained.len(), 1, "exactly one Died transition");
    let (dead_id, moved, failed) = drained[0];
    assert_eq!(dead_id, victim);
    assert_eq!(failed, 0, "15 survivors: no queued instance lacks a home");
    assert!(
        (1..=2).contains(&moved),
        "both later runs' victim instances were queued; got moved={moved}"
    );
    let cands = bed.faas.candidates_of("chaos", "f").unwrap();
    assert_eq!(cands.len(), 15, "dead resource stripped from candidates");
    assert!(!cands.contains(&victim));
    // Release the in-flight instance: every run must now complete — the
    // drained instances on survivors, the gated one on the (half-)dead
    // node it already executed on.
    gate.release();
    for run in runs {
        bed.faas.wait_workflow(run, 60.0).unwrap();
    }
}

#[test]
fn flapping_resource_is_quarantined_then_readmitted() {
    let bed = chaos_bed(16);
    let victim = bed.resources[0];
    fanout_app(&bed, "flap", &bed.resources, None);
    let recovered = Arc::new(Mutex::new(Vec::new()));
    {
        let recovered = Arc::clone(&recovered);
        bed.faas.on_engine_event(move |_, ev| {
            if let EngineEvent::ResourceRecovered { resource } = ev {
                recovered.lock().unwrap().push(*resource);
            }
        });
    }
    bed.faas.refresh_monitor_snapshot();
    bed.handles[0].kill();
    for _ in 0..3 {
        bed.faas.refresh_monitor_snapshot();
    }
    assert_eq!(lease_state(&bed, victim), LeaseState::Dead);
    assert_eq!(bed.faas.candidates_of("flap", "f").unwrap().len(), 15);
    // Back up — but one clean sweep only starts the quarantine
    // (quarantine_sweeps defaults to 2): still excluded from scheduling.
    bed.handles[0].revive();
    bed.faas.refresh_monitor_snapshot();
    let lease = bed.faas.monitor_snapshot().lease_of(victim).unwrap().clone();
    assert_eq!((lease.state, lease.clean_sweeps), (LeaseState::Recovering, 1));
    assert!(!lease.state.schedulable());
    assert_eq!(bed.faas.candidates_of("flap", "f").unwrap().len(), 15);
    assert!(recovered.lock().unwrap().is_empty(), "not re-admitted yet");
    // Second clean sweep: re-admitted, memberships restored, servable.
    bed.faas.refresh_monitor_snapshot();
    assert_eq!(lease_state(&bed, victim), LeaseState::Alive);
    assert_eq!(*recovered.lock().unwrap(), vec![victim]);
    let cands = bed.faas.candidates_of("flap", "f").unwrap();
    assert_eq!(cands.len(), 16, "membership restored after quarantine");
    assert!(cands.contains(&victim));
    let run = bed.faas.submit_workflow("flap", &HashMap::new()).unwrap();
    let result = bed.faas.wait_workflow(run, 60.0).unwrap();
    assert_eq!(result.functions["f"].len(), 16, "restored resource serves again");
}

#[test]
fn single_missed_sweep_is_suspect_not_dead() {
    let bed = chaos_bed(16);
    let victim = bed.resources[8];
    fanout_app(&bed, "slow", &bed.resources, None);
    bed.faas.refresh_monitor_snapshot();
    // One slow/missed scrape: Suspect, still schedulable, nothing drained
    // or stripped.
    bed.handles[8].fail_usage.store(true, Ordering::SeqCst);
    bed.faas.refresh_monitor_snapshot();
    let lease = bed.faas.monitor_snapshot().lease_of(victim).unwrap().clone();
    assert_eq!((lease.state, lease.misses), (LeaseState::Suspect, 1));
    assert!(lease.state.schedulable());
    assert_eq!(bed.faas.candidates_of("slow", "f").unwrap().len(), 16);
    // The next sweep answers: straight back to Alive — Suspect was never
    // drained, so there is no quarantine.
    bed.handles[8].fail_usage.store(false, Ordering::SeqCst);
    bed.faas.refresh_monitor_snapshot();
    assert_eq!(lease_state(&bed, victim), LeaseState::Alive);
    let run = bed.faas.submit_workflow("slow", &HashMap::new()).unwrap();
    let result = bed.faas.wait_workflow(run, 60.0).unwrap();
    assert_eq!(result.functions["f"].len(), 16);
}

#[test]
fn no_surviving_candidate_fails_typed_and_never_hangs() {
    let bed = chaos_bed(16);
    let victim = bed.resources[5];
    fanout_app(&bed, "pinned", &[victim], None);
    // Killed before the detector's first sweep ever saw it: the batch
    // path's direct probe, not the lease, must classify the death.
    bed.handles[5].kill();
    let run = bed.faas.submit_workflow("pinned", &HashMap::new()).unwrap();
    let err = bed.faas.wait_workflow(run, 60.0).expect_err("no survivor: the run must fail");
    match err {
        WaitError::ResourceDead { resource, message, .. } => {
            assert_eq!(resource, victim);
            assert!(message.contains("ResourceDead"), "{message}");
        }
        other => panic!("expected a typed ResourceDead failure, got {other:?}"),
    }
}

#[test]
fn half_dead_resource_executes_at_most_once() {
    let bed = chaos_bed(16);
    let victim = bed.resources[7];
    let executions = fanout_app(&bed, "halfdead", &[victim], None);
    bed.faas.refresh_monitor_snapshot();
    // The node executes the batch but its reply is lost and its scrape
    // times out — from the coordinator's side indistinguishable from a
    // crash mid-call. Sole candidate, so the retry lands on the same node,
    // where the attempt-id cache must replay instead of re-executing.
    bed.handles[7].lose_next_reply.store(true, Ordering::SeqCst);
    bed.handles[7].fail_usage.store(true, Ordering::SeqCst);
    let run = bed.faas.submit_workflow("halfdead", &HashMap::new()).unwrap();
    let result = bed.faas.wait_workflow(run, 60.0).unwrap();
    assert_eq!(result.functions["f"].len(), 1);
    assert_eq!(
        executions.load(Ordering::SeqCst),
        1,
        "the lost-reply retry must replay the recorded result, not run the handler again"
    );
}

#[test]
fn chaos_outcome_is_identical_across_engine_shard_counts() {
    let mut outcomes = Vec::new();
    for shards in [1usize, 16] {
        let bed = chaos_bed(16);
        bed.faas.set_engine_shards(shards);
        let victim = bed.resources[9];
        fanout_app(&bed, "det", &bed.resources, None);
        bed.faas.refresh_monitor_snapshot();
        bed.handles[9].kill();
        for _ in 0..3 {
            bed.faas.refresh_monitor_snapshot();
        }
        let run = bed.faas.submit_workflow("det", &HashMap::new()).unwrap();
        let result = bed.faas.wait_workflow(run, 60.0).unwrap();
        let mut placements: Vec<ResourceId> =
            result.functions["f"].iter().map(|i| i.resource).collect();
        placements.sort_unstable();
        outcomes.push((
            lease_state(&bed, victim),
            bed.faas.candidates_of("det", "f").unwrap(),
            placements,
        ));
    }
    assert_eq!(
        outcomes[0], outcomes[1],
        "detection, candidate stripping and placements must not depend on shard count"
    );
}

#[test]
fn killing_a_tenth_of_a_1k_fleet_mid_population_never_hangs() {
    use edgefaas::workloads::{generate, PopulationSpec};

    // Liveness at harness scale (ISSUE 8, satellite c): a 1k-resource
    // fleet serving a seeded population loses 10% of its nodes mid-run.
    // Every submission must either complete or fail *typed*
    // (`WaitError::ResourceDead`) — no run may hang, and the survivors
    // must carry the large majority of the population.
    const FLEET: usize = 1000;
    const APPS: usize = 50;
    let bed = chaos_bed(FLEET);
    bed.faas.set_backpressure(1_000_000, 1_000_000);
    let gate = Gate::new();
    // 50 single-anchor apps spread over the fleet: anchors 0, 20, ...,
    // 980. The kill below takes out resources 0..100, i.e. 5 of the 50
    // anchors — their populations lose every candidate.
    for c in 0..APPS {
        let anchor = bed.resources[c * (FLEET / APPS)];
        let g = if c == 0 { Some((anchor, Arc::clone(&gate))) } else { None };
        fanout_app(&bed, &format!("pop{c}"), &[anchor], g);
    }
    bed.faas.refresh_monitor_snapshot();

    // A seeded population mapped onto the apps: device `d` lives in cell
    // `d % APPS`, and each submission targets its cell's app.
    let schedule = generate(&PopulationSpec::standard(0xC0FFEE, FLEET, APPS, 20.0));
    assert!(schedule.len() >= 100, "population too small: {}", schedule.len());
    let half = schedule.len() / 2;
    let mut runs: Vec<RunId> = Vec::new();

    // Park one pop0 handler on its (soon-dead) anchor so the kill lands
    // with work genuinely in flight, then submit the first half.
    runs.push(bed.faas.submit_workflow("pop0", &HashMap::new()).unwrap());
    while gate.entered.load(Ordering::SeqCst) == 0 {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    for sub in &schedule[..half] {
        runs.push(bed.faas.submit_workflow(&format!("pop{}", sub.cell), &HashMap::new()).unwrap());
    }

    // Kill 10% of the fleet and keep submitting before the detector has
    // seen anything — dead-anchor dispatches must be classified by the
    // batch path's direct probe, not a lucky sweep ordering.
    for h in &bed.handles[..FLEET / 10] {
        h.kill();
    }
    for sub in &schedule[half..] {
        runs.push(bed.faas.submit_workflow(&format!("pop{}", sub.cell), &HashMap::new()).unwrap());
    }
    // Now let the lease detector walk the victims to Dead (1 miss =
    // Suspect, 3 = Dead) and drain their queues.
    for _ in 0..3 {
        bed.faas.refresh_monitor_snapshot();
    }
    gate.release();

    let (mut completed, mut dead) = (0usize, 0usize);
    for run in runs {
        match bed.faas.wait_workflow(run, 120.0) {
            Ok(_) => completed += 1,
            Err(WaitError::ResourceDead { .. }) => dead += 1,
            Err(other) => panic!("run neither completed nor failed typed: {other:?}"),
        }
    }
    assert!(dead >= 1, "five sole anchors died: some runs must fail typed");
    assert!(
        completed * 10 >= (completed + dead) * 8,
        "survivors must carry the large majority: {completed} completed, {dead} dead"
    );
}

#[test]
fn unregister_of_a_busy_resource_is_refused_with_live_runs() {
    let bed = chaos_bed(2);
    let blocker = bed.resources[0];
    let victim = bed.resources[1];
    let gate = Gate::new();
    fanout_app(&bed, "blocker", &[blocker], Some((blocker, Arc::clone(&gate))));
    fanout_app(&bed, "solo", &[victim], None);
    // One worker total: it parks inside the blocker's gate, so solo's
    // instance stays queued on the victim.
    bed.faas.set_engine_limits(1, 4);
    let blocker_run = bed.faas.submit_workflow("blocker", &HashMap::new()).unwrap();
    while gate.entered.load(Ordering::SeqCst) == 0 {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let solo_run = bed.faas.submit_workflow("solo", &HashMap::new()).unwrap();
    // Clear the victim's deployments directly so the *engine* refusal —
    // not the deployed-functions check — is what unregister hits. This is
    // the historical hang: the resource looks clean, but a queued instance
    // still needs it.
    let reg = bed.faas.resource(victim).unwrap();
    reg.handle.remove("solo.f").unwrap();
    let err = bed.faas.unregister(victim).unwrap_err();
    let busy = err.downcast_ref::<ResourceBusy>().expect("typed ResourceBusy refusal");
    assert_eq!(busy.resource, victim);
    assert!(busy.queued >= 1, "{busy}");
    assert!(busy.runs.contains(&solo_run), "refusal names the live run: {busy}");
    // Make the function servable again, unblock, and prove nothing hangs.
    reg.handle.deploy("solo.f", "img/solo", 128 << 20, 0, &[]).unwrap();
    gate.release();
    bed.faas.wait_workflow(blocker_run, 60.0).unwrap();
    bed.faas.wait_workflow(solo_run, 60.0).unwrap();
    // With its queue drained and functions gone, unregistration goes
    // through.
    reg.handle.remove("solo.f").unwrap();
    bed.faas.unregister(victim).unwrap();
}

// ==================== wire-fault partition suite =========================

/// A bed where every resource really is three sockets: a [`FaasGateway`],
/// a [`MetricsGateway`] exporter, and a [`StoreGateway`], driven through an
/// [`HttpHandle`] — so the seeded fault plane can partition a node at the
/// wire without any test-double handle in the path.
struct WireBed {
    faas: Arc<EdgeFaaS>,
    executor: Arc<NativeExecutor>,
    resources: Vec<ResourceId>,
    faas_addrs: Vec<String>,
    metrics_addrs: Vec<String>,
    /// Listeners stay alive for the bed's lifetime.
    _servers: Vec<Server>,
}

/// Tight per-verb budgets so a black-holed peer costs hundreds of
/// milliseconds, not the 60 s production defaults.
fn wire_budgets() -> VerbBudgets {
    VerbBudgets {
        connect: Duration::from_millis(250),
        control: Duration::from_secs(5),
        usage: Duration::from_millis(200),
        object: Duration::from_secs(5),
        invoke: Duration::from_millis(400),
        federation: Duration::from_millis(400),
        retries: 1,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(5),
        retry: true,
    }
}

fn wire_bed(n: usize) -> WireBed {
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
    let mut topo = Topology::new();
    let hub = topo.add_node("hub", Tier::Edge);
    let nodes: Vec<usize> = (0..n)
        .map(|i| {
            let node = topo.add_node(format!("wire-{i}"), Tier::Iot);
            topo.add_link(node, hub, 0.001, mbps(100.0));
            node
        })
        .collect();
    let executor = Arc::new(NativeExecutor::new());
    let faas =
        Arc::new(EdgeFaaS::with_parts(topo, DurableKv::ephemeral(), Arc::clone(&clock)));
    let mut resources = Vec::new();
    let (mut faas_addrs, mut metrics_addrs) = (Vec::new(), Vec::new());
    let mut servers = Vec::new();
    for (i, &node) in nodes.iter().enumerate() {
        let spec = ResourceSpec::paper_iot(&format!("wire{i}:8080"));
        let backend = Arc::new(FaasBackend::new(
            spec.clone(),
            Arc::clone(&executor) as Arc<dyn Executor>,
            Arc::clone(&clock),
        ));
        let gw =
            Server::bind(0, 4, Arc::new(FaasGateway::new(backend)) as Arc<dyn Handler>).unwrap();
        let registry = Arc::new(MetricsRegistry::new());
        registry.record_usage(&ResourceUsage {
            mem_total: spec.total_memory(),
            gpus_total: spec.total_gpus(),
            ..ResourceUsage::default()
        });
        let metrics = MetricsGateway::serve(registry).unwrap();
        let store = Arc::new(ObjectStore::new(
            spec.storage * spec.nodes as u64,
            &spec.minio_access_key,
            &spec.minio_secret_key,
        ));
        let minio =
            Server::bind(0, 2, Arc::new(StoreGateway::new(store)) as Arc<dyn Handler>).unwrap();
        let handle = HttpHandle::new(
            gw.addr(),
            spec.pwd.as_str(),
            minio.addr(),
            spec.minio_access_key.as_str(),
            spec.minio_secret_key.as_str(),
            metrics.addr(),
        )
        .with_budgets(wire_budgets());
        let id = faas
            .register(spec, Arc::new(handle) as Arc<dyn ResourceHandle>, node)
            .unwrap();
        resources.push(id);
        faas_addrs.push(gw.addr());
        metrics_addrs.push(metrics.addr());
        servers.extend([gw, metrics, minio]);
    }
    WireBed { faas, executor, resources, faas_addrs, metrics_addrs, _servers: servers }
}

/// Configure + deploy (over the real sockets) a single-function app
/// fanning one instance onto each anchor.
fn wire_app(bed: &WireBed, app: &str, anchors: &[ResourceId]) {
    let img = format!("img/{app}");
    bed.executor.register(&img, |_: &[u8]| Ok(br#"{"outputs":[]}"#.to_vec()));
    let yaml = format!(
        "\
application: {app}
entrypoint: f
dag:
  - name: f
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
"
    );
    let mut data = HashMap::new();
    data.insert("f".to_string(), anchors.to_vec());
    bed.faas.configure_application(&yaml, &data).unwrap();
    bed.faas.deploy_function(app, "f", &FunctionPackage { code: img }).unwrap();
}

/// The acceptance arc for a full partition: the victim turns Suspect from
/// *live traffic* strictly before any detector sweep has run, the faulted
/// run still completes (relocated off the victim), sweeps then walk the
/// lease to Dead and drain, and healing the wire re-admits the node.
#[test]
fn fully_partitioned_resource_goes_suspect_from_live_traffic_before_any_sweep() {
    let _guard = faults::test_guard();
    let bed = wire_bed(4);
    let victim = bed.resources[2];
    wire_app(&bed, "part", &bed.resources);
    let dead_events = Arc::new(Mutex::new(Vec::new()));
    {
        let dead_events = Arc::clone(&dead_events);
        bed.faas.on_engine_event(move |_, ev| {
            if let EngineEvent::ResourceDead { resource, .. } = ev {
                dead_events.lock().unwrap().push(*resource);
            }
        });
    }
    // Partition the victim in both planes: invokes and scrapes black-hole.
    // Rules are tagged logically so draws don't depend on the OS-assigned
    // ports.
    faults::injector().install(41);
    faults::injector().add_rule(
        FaultRule::new(&bed.faas_addrs[2], FaultKind::BlackHole).tagged("victim-faas"),
    );
    faults::injector().add_rule(
        FaultRule::new(&bed.metrics_addrs[2], FaultKind::BlackHole).tagged("victim-metrics"),
    );
    assert!(
        bed.faas.monitor_snapshot().lease_of(victim).is_none(),
        "precondition: no sweep has ever run"
    );

    // The victim's instance rides its budget into the black hole, the
    // engine reports the miss, probes, and relocates: the run completes.
    let run = bed.faas.submit_workflow("part", &HashMap::new()).unwrap();
    let result = bed.faas.wait_workflow(run, 60.0).unwrap();
    assert_eq!(result.functions["f"].len(), 4);
    assert!(
        result.functions["f"].iter().all(|i| i.resource != victim),
        "the partitioned instance must have relocated to a survivor"
    );

    // Data-path evidence alone created the Suspect lease — strictly before
    // the first sweep: the survivors have no leases at all, so no sweep
    // can have run.
    let snap = bed.faas.monitor_snapshot();
    let lease = snap.lease_of(victim).expect("lease born from data-path evidence");
    assert_eq!(lease.state, LeaseState::Suspect);
    assert!(lease.misses >= 1);
    for &other in &bed.resources {
        if other != victim {
            assert!(snap.lease_of(other).is_none(), "no sweep ran yet");
        }
    }
    assert!(dead_events.lock().unwrap().is_empty(), "Suspect must not drain");

    // Sweeps take over: the data-path miss already counts, so two sweep
    // misses (not dead_after = 3) reach Dead — live traffic bought the
    // detector a whole sweep period.
    bed.faas.refresh_monitor_snapshot();
    assert_eq!(bed.faas.monitor_snapshot().lease_of(victim).unwrap().state, LeaseState::Suspect);
    bed.faas.refresh_monitor_snapshot();
    assert_eq!(bed.faas.monitor_snapshot().lease_of(victim).unwrap().state, LeaseState::Dead);
    assert_eq!(*dead_events.lock().unwrap(), vec![victim]);
    let cands = bed.faas.candidates_of("part", "f").unwrap();
    assert_eq!(cands.len(), 3, "dead resource stripped from candidates");
    assert!(!cands.contains(&victim));
    let run = bed.faas.submit_workflow("part", &HashMap::new()).unwrap();
    let result = bed.faas.wait_workflow(run, 60.0).unwrap();
    assert_eq!(result.functions["f"].len(), 3, "survivors carry the run during the partition");

    // Heal the wire: two clean sweeps re-admit the node.
    faults::injector().heal(&bed.faas_addrs[2]);
    faults::injector().heal(&bed.metrics_addrs[2]);
    bed.faas.refresh_monitor_snapshot();
    assert_eq!(
        bed.faas.monitor_snapshot().lease_of(victim).unwrap().state,
        LeaseState::Recovering
    );
    bed.faas.refresh_monitor_snapshot();
    assert_eq!(bed.faas.monitor_snapshot().lease_of(victim).unwrap().state, LeaseState::Alive);
    let cands = bed.faas.candidates_of("part", "f").unwrap();
    assert_eq!(cands.len(), 4, "membership restored after the partition heals");
    let run = bed.faas.submit_workflow("part", &HashMap::new()).unwrap();
    let result = bed.faas.wait_workflow(run, 60.0).unwrap();
    assert_eq!(result.functions["f"].len(), 4, "healed resource serves again");
    faults::injector().clear();
}

/// An asymmetric partition: the coordinator's traffic to the victim is
/// black-holed while any other vantage point still reaches it. The
/// coordinator must treat its own view as authoritative (Suspect +
/// relocation), yet a differently-labelled prober proves the node is up.
#[test]
fn asymmetric_partition_is_detected_by_the_coordinator_but_not_the_prober() {
    let _guard = faults::test_guard();
    let bed = wire_bed(2);
    let victim = bed.resources[1];
    wire_app(&bed, "asym", &bed.resources);
    faults::injector().install(59);
    faults::injector().set_source("coordinator");
    faults::injector().add_rule(
        FaultRule::new(&bed.faas_addrs[1], FaultKind::BlackHole)
            .from_src("coordinator")
            .tagged("asym-faas"),
    );
    faults::injector().add_rule(
        FaultRule::new(&bed.metrics_addrs[1], FaultKind::BlackHole)
            .from_src("coordinator")
            .tagged("asym-metrics"),
    );

    let run = bed.faas.submit_workflow("asym", &HashMap::new()).unwrap();
    let result = bed.faas.wait_workflow(run, 60.0).unwrap();
    assert_eq!(result.functions["f"].len(), 2);
    assert!(result.functions["f"].iter().all(|i| i.resource != victim));
    let snap = bed.faas.monitor_snapshot();
    assert_eq!(snap.lease_of(victim).map(|l| l.state), Some(LeaseState::Suspect));
    assert!(snap.lease_of(bed.resources[0]).is_none(), "evidence is data-path only");

    // Same endpoint, other side of the cut: the prober's scrape succeeds
    // where the coordinator's black-holes.
    let opts = || RequestOptions::budget(Duration::from_millis(250), Duration::from_millis(300));
    faults::injector().set_source("prober");
    assert!(
        scrape_with(&bed.metrics_addrs[1], opts()).is_ok(),
        "the node is alive and reachable from outside the cut"
    );
    faults::injector().set_source("coordinator");
    assert!(scrape_with(&bed.metrics_addrs[1], opts()).is_err(), "the cut still holds");
    faults::injector().clear();
}

/// One seeded pass over a flaky wire: 6 sequential runs against a sole
/// anchor behind a probabilistic reset rule. Returns a printable digest of
/// every run outcome plus the victim's final lease and candidacy.
fn wire_fault_digest(seed: u64, shards: usize) -> String {
    let bed = wire_bed(3);
    bed.faas.set_engine_shards(shards);
    let victim = bed.resources[1];
    wire_app(&bed, "det", &[victim]);
    faults::injector().install(seed);
    faults::injector().add_rule(
        FaultRule::new(&bed.faas_addrs[1], FaultKind::ErrorRate { rate: 0.35 })
            .tagged("det-flaky"),
    );
    let mut outcomes = Vec::new();
    for _ in 0..6 {
        match bed.faas.submit_workflow("det", &HashMap::new()) {
            Err(_) => outcomes.push("rejected".to_string()),
            Ok(run) => match bed.faas.wait_workflow(run, 60.0) {
                Ok(r) => outcomes.push(format!("ok:{}", r.functions["f"].len())),
                Err(_) => outcomes.push("failed".to_string()),
            },
        }
    }
    let lease = bed
        .faas
        .monitor_snapshot()
        .lease_of(victim)
        .map(|l| format!("{:?}/{}", l.state, l.misses))
        .unwrap_or_else(|| "none".to_string());
    let cands = bed.faas.candidates_of("det", "f").unwrap_or_default();
    faults::injector().clear();
    format!("runs={outcomes:?} lease={lease} cands={cands:?}")
}

/// The fault plane's determinism contract at the acceptance boundary:
/// for a fixed fault seed the full outcome digest — per-run results, the
/// victim's lease trajectory, candidate stripping — is byte-identical
/// whether the engine runs 1 shard or 16. (Draws are keyed by logical rule
/// tag + request identity, never by port, thread, or wall clock.)
#[test]
fn wire_fault_outcomes_are_identical_per_seed_across_shard_counts() {
    let _guard = faults::test_guard();
    for seed in [11u64, 1213] {
        let one = wire_fault_digest(seed, 1);
        let sixteen = wire_fault_digest(seed, 16);
        assert_eq!(one, sixteen, "seed {seed}: outcome must not depend on shard count");
    }
}
