//! Failure injection: the coordinator's behaviour when resources misbehave
//! — partial deploy failures, invocation errors, unreachable monitoring,
//! capacity exhaustion mid-workflow. The paper specifies several of these
//! behaviours explicitly (§3.2.1: failed resource IDs are returned and
//! removed from the candidate mapping).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use edgefaas::coordinator::functions::FunctionPackage;
use edgefaas::coordinator::handle::ResourceHandle;
use edgefaas::util::bytes::Bytes;
use edgefaas::monitor::metrics::ResourceUsage;
use edgefaas::simnet::RealClock;
use edgefaas::testbed::paper_testbed;
use edgefaas::util::json::Json;

/// A handle wrapper that can be told to fail specific verbs.
struct FlakyHandle {
    inner: Arc<dyn ResourceHandle>,
    fail_deploy: AtomicBool,
    fail_invoke: AtomicBool,
    fail_usage: AtomicBool,
    invokes: AtomicUsize,
}

impl FlakyHandle {
    fn wrap(inner: Arc<dyn ResourceHandle>) -> Arc<FlakyHandle> {
        Arc::new(FlakyHandle {
            inner,
            fail_deploy: AtomicBool::new(false),
            fail_invoke: AtomicBool::new(false),
            fail_usage: AtomicBool::new(false),
            invokes: AtomicUsize::new(0),
        })
    }
}

impl ResourceHandle for FlakyHandle {
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        if self.fail_deploy.load(Ordering::SeqCst) {
            anyhow::bail!("injected deploy failure");
        }
        self.inner.deploy(name, image, memory, gpus, labels)
    }

    fn remove(&self, name: &str) -> anyhow::Result<()> {
        self.inner.remove(name)
    }

    fn invoke(&self, name: &str, payload: &Bytes) -> anyhow::Result<(Bytes, f64)> {
        self.invokes.fetch_add(1, Ordering::SeqCst);
        if self.fail_invoke.load(Ordering::SeqCst) {
            anyhow::bail!("injected invoke failure");
        }
        self.inner.invoke(name, payload)
    }

    fn list(&self) -> anyhow::Result<Vec<String>> {
        self.inner.list()
    }

    fn describe(&self, name: &str) -> anyhow::Result<Json> {
        self.inner.describe(name)
    }

    fn usage(&self) -> anyhow::Result<ResourceUsage> {
        if self.fail_usage.load(Ordering::SeqCst) {
            anyhow::bail!("injected scrape failure");
        }
        self.inner.usage()
    }

    fn make_bucket(&self, b: &str) -> anyhow::Result<()> {
        self.inner.make_bucket(b)
    }
    fn remove_bucket(&self, b: &str) -> anyhow::Result<()> {
        self.inner.remove_bucket(b)
    }
    fn put_object(&self, b: &str, o: &str, d: Bytes) -> anyhow::Result<()> {
        self.inner.put_object(b, o, d)
    }
    fn get_object(&self, b: &str, o: &str) -> anyhow::Result<Bytes> {
        self.inner.get_object(b, o)
    }
    fn remove_object(&self, b: &str, o: &str) -> anyhow::Result<()> {
        self.inner.remove_object(b, o)
    }
    fn list_objects(&self, b: &str) -> anyhow::Result<Vec<String>> {
        self.inner.list_objects(b)
    }
    fn stored_bytes(&self) -> anyhow::Result<u64> {
        self.inner.stored_bytes()
    }
}

/// Testbed where one IoT resource is wrapped in a FlakyHandle.
fn flaky_bed() -> (edgefaas::testbed::TestBed, Arc<FlakyHandle>, u32) {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    // Re-register pi 7 behind a flaky wrapper (unregister requires it to be
    // clean, which a fresh testbed satisfies).
    let victim = bed.iot[7];
    let reg = bed.faas.resource(victim).unwrap();
    let flaky = FlakyHandle::wrap(Arc::clone(&reg.handle));
    let (spec, node) = (reg.spec.clone(), reg.net_node);
    bed.faas.unregister(victim).unwrap();
    let new_id = bed
        .faas
        .register(spec, Arc::clone(&flaky) as Arc<dyn ResourceHandle>, node)
        .unwrap();
    assert_eq!(new_id, victim, "id reuse keeps the testbed layout");
    (bed, flaky, victim)
}

#[test]
fn partial_deploy_failure_prunes_candidates_per_paper() {
    let (bed, flaky, victim) = flaky_bed();
    bed.executor.register("img/x", |p: &[u8]| Ok(p.to_vec()));
    let yaml = edgefaas::coordinator::appconfig::federated_learning_yaml();
    let mut data = HashMap::new();
    data.insert("train".to_string(), bed.iot.clone());
    bed.faas.configure_application(yaml, &data).unwrap();
    flaky.fail_deploy.store(true, Ordering::SeqCst);
    // "If the function fails to be created on some resources,
    // create_function() returns error and the failed resource IDs...
    // removed from the candidate resource mapping."
    let err = bed
        .faas
        .deploy_function("federatedlearning", "train", &FunctionPackage { code: "img/x".into() })
        .unwrap_err()
        .to_string();
    assert!(err.contains(&victim.to_string()), "error names the failed id: {err}");
    let remaining = bed.faas.candidates_of("federatedlearning", "train").unwrap();
    assert_eq!(remaining.len(), 7);
    assert!(!remaining.contains(&victim), "failed id pruned from mapping");
    // The other 7 deployments are live and invocable.
    let results = bed.faas.invoke("federatedlearning", "train", &Json::obj(), false).unwrap();
    assert_eq!(results.len(), 7);
}

#[test]
fn invoke_failure_propagates_with_resource_id() {
    let (bed, flaky, victim) = flaky_bed();
    bed.executor.register("img/x", |p: &[u8]| Ok(p.to_vec()));
    let yaml = edgefaas::coordinator::appconfig::federated_learning_yaml();
    let mut data = HashMap::new();
    data.insert("train".to_string(), bed.iot.clone());
    bed.faas.configure_application(yaml, &data).unwrap();
    bed.faas
        .deploy_function("federatedlearning", "train", &FunctionPackage { code: "img/x".into() })
        .unwrap();
    flaky.fail_invoke.store(true, Ordering::SeqCst);
    let err =
        bed.faas.invoke("federatedlearning", "train", &Json::obj(), false).unwrap_err().to_string();
    assert!(err.contains("injected invoke failure"), "{err}");
    let _ = victim;
}

#[test]
fn unreachable_monitoring_filters_resource_out() {
    let (bed, flaky, victim) = flaky_bed();
    flaky.fail_usage.store(true, Ordering::SeqCst);
    // Schedule an IoT function over all Pis: the scrape-failing one must be
    // dropped by phase 1 (fail-safe: no metrics, no placement).
    let yaml = edgefaas::coordinator::appconfig::federated_learning_yaml();
    let mut data = HashMap::new();
    data.insert("train".to_string(), bed.iot.clone());
    let plan = bed.faas.configure_application(yaml, &data).unwrap();
    assert_eq!(plan["train"].len(), 7);
    assert!(!plan["train"].contains(&victim));
}

#[test]
fn workflow_fails_cleanly_when_a_stage_errors() {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let faas = Arc::clone(&bed.faas);
    bed.executor.register("img/ok", |_: &[u8]| {
        Ok(br#"{"outputs":[]}"#.to_vec())
    });
    bed.executor.register("img/boom", |_: &[u8]| anyhow::bail!("stage exploded"));
    let yaml = "\
application: fragile
entrypoint: a
dag:
  - name: a
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
  - name: b
    dependencies: a
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: 1
";
    let mut data = HashMap::new();
    data.insert("a".to_string(), vec![bed.iot[0]]);
    faas.configure_application(yaml, &data).unwrap();
    faas.deploy_function("fragile", "a", &FunctionPackage { code: "img/ok".into() }).unwrap();
    faas.deploy_function("fragile", "b", &FunctionPackage { code: "img/boom".into() }).unwrap();
    let err = faas.run_workflow("fragile", &HashMap::new()).unwrap_err().to_string();
    assert!(err.contains("stage exploded"), "{err}");
}

#[test]
fn capacity_exhaustion_surfaces_as_invocation_error() {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    // A function whose sandbox takes 3 GB on a 4 GB Pi: the second
    // *concurrent* admission must fail (paper: resources are finite).
    let reg = bed.faas.resource(bed.iot[0]).unwrap();
    bed.executor.register("img/hold", |_: &[u8]| {
        std::thread::sleep(std::time::Duration::from_millis(300));
        Ok(vec![])
    });
    reg.handle.deploy("big", "img/hold", 3 << 30, 0, &[]).unwrap();
    let h = Arc::clone(&reg.handle);
    let t = std::thread::spawn(move || h.invoke("big", &Bytes::new()));
    std::thread::sleep(std::time::Duration::from_millis(50));
    let second = reg.handle.invoke("big", &Bytes::new());
    assert!(second.is_err(), "no memory for a second sandbox");
    assert!(t.join().unwrap().is_ok(), "first invocation unaffected");
    // After the first completes, capacity is back (warm sandbox reused).
    let third = reg.handle.invoke("big", &Bytes::new());
    assert!(third.is_ok());
}

#[test]
fn store_full_surfaces_through_virtual_storage() {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let faas = Arc::clone(&bed.faas);
    faas.create_bucket("fillme", "data", Some(bed.iot[0])).unwrap();
    // A Pi's store is 64 GB; don't fill it — use a tiny custom resource
    // instead: emulate by writing one object larger than free capacity.
    let huge = vec![0u8; 1 << 20];
    // 64 GB / 1 MiB = 65536 objects — too slow; instead assert the error
    // path via the store's own capacity check with an oversized single
    // object on a tiny ObjectStore.
    let small = edgefaas::objstore::ObjectStore::new(512, "ak", "sk");
    small.make_bucket("data").unwrap();
    let err = small.put_object("data", "big", huge.into()).unwrap_err();
    assert!(matches!(err, edgefaas::objstore::store::StoreError::Full { .. }));
}
