//! Property tests over coordinator invariants (hand-rolled, PCG-driven —
//! proptest is unavailable offline). Each test sweeps hundreds of random
//! topologies / applications / workloads and asserts structural invariants
//! of routing, scheduling and storage state.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use edgefaas::backup::DurableKv;
use edgefaas::cluster::faas::{Executor, FaasBackend, NativeExecutor};
use edgefaas::cluster::spec::ResourceSpec;
use edgefaas::coordinator::handle::LocalHandle;
use edgefaas::coordinator::{
    Affinity, AffinityType, EdgeFaaS, FunctionConfig, FunctionCreation, Reduce, Requirements,
    ResourceId,
};
use edgefaas::objstore::ObjectStore;
use edgefaas::simnet::topology::mbps;
use edgefaas::simnet::{RealClock, Tier, Topology};
use edgefaas::util::rng::Pcg32;

/// A random 3-tier star-of-stars topology + coordinator.
/// Returns (faas, iot ids, edge ids, cloud ids).
fn random_bed(
    rng: &mut Pcg32,
) -> (Arc<EdgeFaaS>, Vec<ResourceId>, Vec<ResourceId>, Vec<ResourceId>) {
    let n_edge = rng.range(1, 4);
    let n_cloud = rng.range(1, 3);
    let n_iot = rng.range(1, 10);
    let mut topo = Topology::new();
    let clock: Arc<dyn edgefaas::simnet::Clock> = Arc::new(RealClock::new());
    let executor = Arc::new(NativeExecutor::new());

    let edge_nodes: Vec<usize> =
        (0..n_edge).map(|i| topo.add_node(format!("e{i}"), Tier::Edge)).collect();
    let cloud_nodes: Vec<usize> =
        (0..n_cloud).map(|i| topo.add_node(format!("c{i}"), Tier::Cloud)).collect();
    let iot_nodes: Vec<usize> =
        (0..n_iot).map(|i| topo.add_node(format!("p{i}"), Tier::Iot)).collect();
    for (i, &p) in iot_nodes.iter().enumerate() {
        let e = edge_nodes[i % n_edge];
        topo.add_link(p, e, 0.0005 + rng.next_f64() * 0.02, mbps(50.0 + rng.next_f64() * 100.0));
    }
    for &e in &edge_nodes {
        for &c in &cloud_nodes {
            topo.add_link(e, c, 0.002 + rng.next_f64() * 0.08, mbps(5.0 + rng.next_f64() * 20.0));
        }
    }
    let faas = Arc::new(EdgeFaaS::with_parts(topo, DurableKv::ephemeral(), Arc::clone(&clock)));
    let mk = |spec: ResourceSpec, node: usize, faas: &EdgeFaaS| -> ResourceId {
        let backend = Arc::new(FaasBackend::new(
            spec.clone(),
            Arc::clone(&executor) as Arc<dyn Executor>,
            Arc::clone(&clock),
        ));
        let store = Arc::new(ObjectStore::new(spec.storage, "ak", "sk"));
        faas.register(spec, Arc::new(LocalHandle::new(backend, store)), node).unwrap()
    };
    let iot: Vec<ResourceId> = iot_nodes
        .iter()
        .enumerate()
        .map(|(i, &n)| mk(ResourceSpec::paper_iot(&format!("p{i}")), n, &faas))
        .collect();
    let edges: Vec<ResourceId> = edge_nodes
        .iter()
        .enumerate()
        .map(|(i, &n)| mk(ResourceSpec::paper_edge(&format!("e{i}")), n, &faas))
        .collect();
    let clouds: Vec<ResourceId> = cloud_nodes
        .iter()
        .enumerate()
        .map(|(i, &n)| mk(ResourceSpec::paper_cloud(&format!("c{i}")), n, &faas))
        .collect();
    (faas, iot, edges, clouds)
}

fn fc(tier: Tier, at: AffinityType, reduce: Reduce, privacy: bool) -> FunctionConfig {
    FunctionConfig {
        name: "f".into(),
        dependencies: vec![],
        requirements: Requirements { memory: 64 << 20, gpu: 0, privacy },
        affinity: Affinity { nodetype: tier, affinitytype: at },
        reduce,
    }
}

/// Invariants of two-phase scheduling across random topologies:
/// 1. every placement is a registered resource of the requested tier;
/// 2. reduce=1 yields exactly one instance;
/// 3. reduce=auto yields <= |upstream| deduplicated instances;
/// 4. privacy=1 places only on data-holding IoT devices;
/// 5. the candidate mapping equals the returned placement.
#[test]
fn prop_scheduling_invariants() {
    let mut rng = Pcg32::seeded(0xC0FFEE);
    for round in 0..150 {
        let (faas, iot, edges, clouds) = random_bed(&mut rng);
        let tier = *rng.choose(&[Tier::Iot, Tier::Edge, Tier::Cloud]);
        let at = *rng.choose(&[AffinityType::Data, AffinityType::Function]);
        let reduce = if rng.next_bool(0.5) { Reduce::One } else { Reduce::Auto };
        let privacy = tier == Tier::Iot && rng.next_bool(0.3);
        let n_up = rng.range(1, iot.len() + 1);
        let mut upstream = iot.clone();
        rng.shuffle(&mut upstream);
        upstream.truncate(n_up);
        let request = FunctionCreation {
            app: format!("app{round}"),
            function: fc(tier, at, reduce, privacy),
            data_locations: upstream.clone(),
            dep_locations: upstream.clone(),
        };
        let placed = faas.schedule_function(&request).unwrap();
        let tier_set: HashSet<ResourceId> = match tier {
            Tier::Iot => iot.iter().copied().collect(),
            Tier::Edge => edges.iter().copied().collect(),
            Tier::Cloud => clouds.iter().copied().collect(),
        };
        assert!(!placed.is_empty());
        for &p in &placed {
            assert!(tier_set.contains(&p), "round {round}: {p} not of tier {tier:?}");
        }
        match reduce {
            Reduce::One => assert_eq!(placed.len(), 1, "round {round}"),
            Reduce::Auto => {
                assert!(placed.len() <= upstream.len(), "round {round}");
                let uniq: HashSet<_> = placed.iter().collect();
                assert_eq!(uniq.len(), placed.len(), "round {round}: duplicates");
            }
        }
        if privacy {
            let data_set: HashSet<_> = upstream.iter().collect();
            for p in &placed {
                assert!(data_set.contains(p), "round {round}: privacy violated");
            }
        }
        assert_eq!(faas.candidates_of(&request.app, "f").unwrap(), placed);
    }
}

/// The locality policy places each upstream's instance at its minimum-
/// latency candidate (optimality of phase 2 under reduce=auto).
#[test]
fn prop_auto_placement_is_latency_optimal() {
    let mut rng = Pcg32::seeded(0xBEEF);
    for round in 0..100 {
        let (faas, iot, edges, _clouds) = random_bed(&mut rng);
        let anchor = *rng.choose(&iot);
        let request = FunctionCreation {
            app: format!("opt{round}"),
            function: fc(Tier::Edge, AffinityType::Data, Reduce::Auto, false),
            data_locations: vec![anchor],
            dep_locations: vec![],
        };
        let placed = faas.schedule_function(&request).unwrap();
        assert_eq!(placed.len(), 1);
        let chosen_lat = faas.latency(anchor, placed[0]).unwrap();
        for &e in &edges {
            let lat = faas.latency(anchor, e).unwrap();
            assert!(
                chosen_lat <= lat + 1e-12,
                "round {round}: chose {} ({chosen_lat}) but {e} is closer ({lat})",
                placed[0]
            );
        }
    }
}

/// Storage invariants under random verb sequences: URL-addressed reads
/// always return the last write; bucket listings match a model map;
/// deletions are exact.
#[test]
fn prop_storage_model_equivalence() {
    let mut rng = Pcg32::seeded(0xD00D);
    for round in 0..40 {
        let (faas, iot, _edges, clouds) = random_bed(&mut rng);
        let app = format!("s{round}");
        let mut model: HashMap<(String, String), Vec<u8>> = HashMap::new();
        let mut buckets: Vec<String> = Vec::new();
        for step in 0..60 {
            match rng.next_below(5) {
                0 => {
                    let name = format!("bucket-{step}");
                    let home = if rng.next_bool(0.5) { *rng.choose(&iot) } else { clouds[0] };
                    faas.create_bucket(&app, &name, Some(home)).unwrap();
                    buckets.push(name);
                }
                1 | 2 if !buckets.is_empty() => {
                    let b = rng.choose(&buckets).clone();
                    let obj = format!("o{}", rng.next_below(5));
                    let data: Vec<u8> = (0..rng.range(1, 64)).map(|_| rng.next_u32() as u8).collect();
                    let url = faas.put_object(&app, &b, &obj, &data).unwrap();
                    assert_eq!(url.application, app);
                    model.insert((b, obj), data);
                }
                3 if !model.is_empty() => {
                    let key = {
                        let keys: Vec<_> = model.keys().cloned().collect();
                        rng.choose(&keys).clone()
                    };
                    faas.delete_object(&app, &key.0, &key.1).unwrap();
                    model.remove(&key);
                }
                _ if !model.is_empty() => {
                    // Read-back check for a random live object.
                    let key = {
                        let keys: Vec<_> = model.keys().cloned().collect();
                        rng.choose(&keys).clone()
                    };
                    let rid = faas.bucket_resource(&app, &key.0).unwrap();
                    let url = edgefaas::coordinator::storage::ObjectUrl {
                        application: app.clone(),
                        bucket: key.0.clone(),
                        resource: rid,
                        object: key.1.clone(),
                    };
                    assert_eq!(&faas.get_object(&url).unwrap(), model.get(&key).unwrap());
                }
                _ => {}
            }
        }
        // Final listing equivalence per bucket.
        for b in &buckets {
            let mut want: Vec<String> = model
                .keys()
                .filter(|(bb, _)| bb == b)
                .map(|(_, o)| o.clone())
                .collect();
            want.sort();
            assert_eq!(faas.list_objects(&app, b).unwrap(), want, "round {round} bucket {b}");
        }
        assert_eq!(faas.list_buckets(&app).len(), buckets.len());
    }
}

/// Random linear applications: configure + schedule, then verify the plan
/// respects the DAG (every function placed after its dependencies, on the
/// declared tier) across random chain lengths and tier assignments.
#[test]
fn prop_random_chain_applications_schedule() {
    let mut rng = Pcg32::seeded(0xFACE);
    for round in 0..80 {
        let (faas, iot, edges, clouds) = random_bed(&mut rng);
        let len = rng.range(2, 6);
        let mut yaml = format!("application: chain{round}\nentrypoint: f0\ndag:\n");
        let mut tiers = Vec::new();
        for i in 0..len {
            // Monotone tiers iot -> edge -> cloud keep the chain realistic.
            let tier = match (i, len) {
                (0, _) => Tier::Iot,
                (i, l) if i + 1 == l && rng.next_bool(0.7) => Tier::Cloud,
                _ => *rng.choose(&[Tier::Edge, Tier::Cloud]),
            };
            tiers.push(tier);
            yaml.push_str(&format!(
                "  - name: f{i}\n{}    affinity:\n      nodetype: {}\n      affinitytype: {}\n    reduce: {}\n",
                if i > 0 { format!("    dependencies: f{}\n", i - 1) } else { String::new() },
                tier.name(),
                if i == 0 { "data" } else { "function" },
                if rng.next_bool(0.5) { "1" } else { "auto" },
            ));
        }
        let mut data = HashMap::new();
        let n_src = rng.range(1, iot.len() + 1);
        data.insert("f0".to_string(), iot[..n_src].to_vec());
        let plan = faas.configure_application(&yaml, &data).unwrap();
        assert_eq!(plan.len(), len);
        for (i, tier) in tiers.iter().enumerate() {
            let set: &[ResourceId] = match tier {
                Tier::Iot => &iot,
                Tier::Edge => &edges,
                Tier::Cloud => &clouds,
            };
            for p in &plan[&format!("f{i}")] {
                assert!(set.contains(p), "round {round} f{i} placed off-tier");
            }
        }
    }
}
