//! Federation plane integration suite (ISSUE 10).
//!
//! Three contracts:
//!
//! 1. **Member-count invariance** — a seeded population replayed through
//!    1, 2, and 4 coordinators over one shared fleet must fold to
//!    byte-identical outcome/firing digests: federation partitions *who
//!    serves a submission*, never *what the submission does*.
//! 2. **Partition degradation** — with the app owner's address
//!    black-holed at the wire (`util::faults`), a relayed submission
//!    fails typed (502, no execution anywhere) while owner-local apps
//!    keep serving; healing the fault restores forwarding, and no
//!    submission ever executes twice.
//! 3. **Work stealing at-most-once** — an idle coordinator pulls queued
//!    instances from an overloaded peer over real sockets and executes
//!    them on the shared backends; every run completes and every
//!    instance executes exactly once.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use edgefaas::coordinator::functions::FunctionPackage;
use edgefaas::coordinator::gateway::EdgeFaasGateway;
use edgefaas::coordinator::{Federation, FederationConfig};
use edgefaas::simnet::{RealClock, VirtualClock};
use edgefaas::testbed::{federated_testbed, paper_testbed, FederatedBed, TestBed};
use edgefaas::util::faults::{self, FaultKind, FaultRule};
use edgefaas::util::http;
use edgefaas::util::json::Json;
use edgefaas::workloads::{
    generate, install_population_federated, run_population_federated, PopulationReport,
    PopulationSpec, RunConfig,
};

// ------------------------------------------------- member-count invariance

const SEED: u64 = 0xFED_5EED;
const DEVICES: usize = 192;
const CELLS: usize = 4;
const DURATION_S: f64 = 15.0;

/// One determinism-mode federated replay of `SEED` on a fresh shared
/// fleet served by `n` coordinators.
fn federated_replay(n: usize) -> PopulationReport {
    let bed = federated_testbed(Arc::new(VirtualClock::new()), n, CELLS, 4);
    for (k, c) in bed.coordinators.iter().enumerate() {
        c.set_backpressure(1_000_000, 1_000_000);
        Federation::enable(c, FederationConfig::new(k as u32, n as u32)).unwrap();
    }
    install_population_federated(&bed.coordinators, &bed.executor, &bed.cell_boxes)
        .expect("install federated population");
    let schedule = generate(&PopulationSpec::standard(SEED, DEVICES, CELLS, DURATION_S));
    assert!(!schedule.is_empty(), "population generated no submissions");
    let report =
        run_population_federated(&bed.coordinators, &schedule, RunConfig::determinism(None));
    assert_eq!(report.hung, 0, "replay hung at {n} coordinator(s)");
    assert_eq!(report.lost, 0, "replay lost run records at {n} coordinator(s)");
    assert_eq!(
        report.completed(),
        report.submitted(),
        "determinism mode must complete every submission at {n} coordinator(s)"
    );
    report
}

#[test]
fn federated_replay_is_member_count_invariant() {
    let single = federated_replay(1);
    let two = federated_replay(2);
    assert_eq!(single.schedule_digest, two.schedule_digest);
    assert_eq!(
        single.firing_digest, two.firing_digest,
        "splitting the fleet across 2 coordinators changed replay outcomes"
    );
    let again = federated_replay(2);
    assert_eq!(two.firing_digest, again.firing_digest, "2-coordinator replay not repeatable");
    let four = federated_replay(4);
    assert_eq!(
        single.firing_digest, four.firing_digest,
        "splitting the fleet across 4 coordinators changed replay outcomes"
    );
}

// ---------------------------------------------------- partition degradation

/// Deploy a single-function app under `app` on `bed`, with an
/// execution-counting handler registered under its own image name.
fn deploy_counting_app(bed: &TestBed, app: &str) -> Arc<AtomicUsize> {
    let count = Arc::new(AtomicUsize::new(0));
    {
        let count = Arc::clone(&count);
        bed.executor.register(&format!("img/count-{app}"), move |_: &[u8]| {
            count.fetch_add(1, Ordering::SeqCst);
            Ok(br#"{"outputs":[]}"#.to_vec())
        });
    }
    let yaml = format!(
        "application: {app}\nentrypoint: f\ndag:\n  - name: f\n    affinity:\n      \
         nodetype: edge\n      affinitytype: data\n    reduce: 1\n"
    );
    let mut data = HashMap::new();
    data.insert("f".to_string(), vec![bed.iot[0]]);
    bed.faas.configure_application(&yaml, &data).unwrap();
    bed.faas
        .deploy_function(app, "f", &FunctionPackage { code: format!("img/count-{app}") })
        .unwrap();
    count
}

/// `fedapp` hashes to member 1 of 2, `asyncdemo` to member 0 (see
/// `Federation::owner_of_app`). Member 0 relays `fedapp` to member 1 and
/// serves `asyncdemo` itself; a wire partition toward member 1 must
/// degrade `fedapp` to a typed 502 without touching `asyncdemo`, and heal
/// cleanly with zero duplicate executions.
#[test]
fn partition_degrades_to_owner_local_and_heals_without_double_execution() {
    let _guard = faults::test_guard();
    let owner_bed = paper_testbed(Arc::new(RealClock::new()));
    let owner_server = EdgeFaasGateway::serve(Arc::clone(&owner_bed.faas), 4).unwrap();
    let owner_addr = owner_server.addr();
    Federation::enable(&owner_bed.faas, FederationConfig::new(1, 2)).unwrap();
    let fedapp_count = deploy_counting_app(&owner_bed, "fedapp");

    let relay_bed = paper_testbed(Arc::new(RealClock::new()));
    let relay_server = EdgeFaasGateway::serve(Arc::clone(&relay_bed.faas), 4).unwrap();
    let relay_fed = Federation::enable(
        &relay_bed.faas,
        FederationConfig::new(0, 2).peer(1, owner_addr.clone()),
    )
    .unwrap();
    let async_count = deploy_counting_app(&relay_bed, "asyncdemo");
    let relay = relay_server.addr();

    // Healthy: the relay forwards to the owner, which executes once.
    let resp = http::post_json(&relay, "/apps/fedapp/run", &Json::obj()).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body_str().unwrap_or(""));
    assert_eq!(fedapp_count.load(Ordering::SeqCst), 1);

    // Partition the wire toward the owner.
    faults::injector().install(17);
    faults::injector().add_rule(FaultRule::new(owner_addr.clone(), FaultKind::ConnectRefused));
    let resp = http::post_json(&relay, "/apps/fedapp/run", &Json::obj()).unwrap();
    assert_eq!(resp.status, 502, "partitioned forward must fail typed");
    let v = resp.json_body().unwrap();
    assert_eq!(v.get("owner").unwrap().as_u64(), Some(1));
    assert_eq!(
        fedapp_count.load(Ordering::SeqCst),
        1,
        "a refused forward must not execute anywhere"
    );
    // Owner-local service is unaffected by the partition.
    let resp = http::post_json(&relay, "/apps/asyncdemo/run", &Json::obj()).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body_str().unwrap_or(""));
    assert_eq!(async_count.load(Ordering::SeqCst), 1);

    // Heal: forwarding resumes, and the healthy + healed submissions add
    // up to exactly one execution each — nothing ran twice.
    faults::injector().heal(&owner_addr);
    let resp = http::post_json(&relay, "/apps/fedapp/run", &Json::obj()).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body_str().unwrap_or(""));
    assert_eq!(fedapp_count.load(Ordering::SeqCst), 2);
    assert_eq!(relay_fed.forward_counters(), (2, 1));
    faults::injector().clear();
}

// ------------------------------------------------- wire steal at-most-once

/// Deploy a single-instance app on `victim` whose handler blocks on a
/// gate and counts executions.
fn deploy_gated_app(
    bed: &FederatedBed,
    victim: usize,
    app: &str,
) -> (Arc<(Mutex<bool>, Condvar)>, Arc<AtomicUsize>) {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let count = Arc::new(AtomicUsize::new(0));
    {
        let gate = Arc::clone(&gate);
        let count = Arc::clone(&count);
        bed.executor.register(&format!("img/gated-{app}"), move |_: &[u8]| {
            let (lock, cv) = &*gate;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            drop(open);
            count.fetch_add(1, Ordering::SeqCst);
            Ok(br#"{"outputs":[]}"#.to_vec())
        });
    }
    let yaml = format!(
        "application: {app}\nentrypoint: f\ndag:\n  - name: f\n    affinity:\n      \
         nodetype: iot\n      affinitytype: data\n    reduce: 1\n"
    );
    let mut data = HashMap::new();
    data.insert("f".to_string(), vec![bed.cell_boxes[0][0]]);
    bed.coordinators[victim].configure_application(&yaml, &data).unwrap();
    bed.coordinators[victim]
        .deploy_function(app, "f", &FunctionPackage { code: format!("img/gated-{app}") })
        .unwrap();
    (gate, count)
}

#[test]
fn wire_steal_executes_every_instance_exactly_once() {
    // Two coordinators over one shared 6-resource fleet, real sockets.
    let bed = federated_testbed(Arc::new(RealClock::new()), 2, 1, 4);
    let victim = Arc::clone(&bed.coordinators[0]);
    let thief = Arc::clone(&bed.coordinators[1]);
    let victim_server = EdgeFaasGateway::serve(Arc::clone(&victim), 4).unwrap();
    let _thief_server = EdgeFaasGateway::serve(Arc::clone(&thief), 4).unwrap();
    Federation::enable(&victim, FederationConfig::new(0, 2)).unwrap();
    let mut thief_cfg = FederationConfig::new(1, 2).peer(0, victim_server.addr());
    thief_cfg.steal_threshold = 2;
    let thief_fed = Federation::enable(&thief, thief_cfg).unwrap();

    // One dispatch shard and one worker on the victim: the first
    // submission blocks in the gated handler, the rest pile up in a
    // single queue the thief's depth poll can see.
    victim.set_engine_shards(1);
    victim.set_engine_limits(1, 8);
    let (gate, count) = deploy_gated_app(&bed, 0, "stealapp");

    const RUNS: usize = 8;
    let ids: Vec<_> = (0..RUNS)
        .map(|_| victim.submit_workflow("stealapp", &HashMap::new()).unwrap())
        .collect();

    // The thief polls the victim over the wire, pulls the queued
    // instances, and re-anchors them — the shared backends make the
    // attempt cache fleet-wide, so nothing can run twice even if the
    // victim later reclaimed a loan.
    let stolen = thief_fed.steal_once();
    assert!(stolen > 0, "an idle thief facing a deep peer queue must steal");
    let (_, hits, stolen_total, _, _) = thief_fed.steal_counters();
    assert_eq!(hits, 1);
    assert_eq!(stolen_total as usize, stolen);

    // Open the gate: the victim's in-flight work and the thief's stolen
    // jobs all drain; every run completes on the victim.
    {
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }
    for id in ids {
        victim.wait_workflow(id, 120.0).unwrap();
    }
    assert_eq!(
        count.load(Ordering::SeqCst),
        RUNS,
        "every instance must execute exactly once across both coordinators"
    );
    let (lent, completed, _requeued, _reclaimed, outstanding) = victim.federation_loans();
    assert_eq!(lent as usize, stolen);
    assert_eq!(completed, lent, "every loan settled by a thief report");
    assert_eq!(outstanding, 0);
}
