//! Network-plane integration: the real REST gateways (FaaS + object store)
//! served over the readiness-driven HTTP stack, exercised through the pooled
//! keep-alive client and through `Connection: close` clients.
//!
//! Every test that touches a server runs against both serve paths — the
//! platform default (the epoll reactor on Linux) and the forced portable
//! fallback — asserting identical REST semantics and, via
//! `Server::connections_accepted`, that keep-alive actually collapses many
//! requests onto few TCP connections.

use std::sync::Arc;

use edgefaas::cluster::faas::{BatchCall, Executor, FaasBackend, NativeExecutor};
use edgefaas::cluster::gateway::{client as faas_client, FaasGateway};
use edgefaas::cluster::spec::ResourceSpec;
use edgefaas::objstore::gateway::{client as store_client, StoreGateway};
use edgefaas::objstore::ObjectStore;
use edgefaas::simnet::RealClock;
use edgefaas::util::bytes::Bytes;
use edgefaas::util::http::{self, Handler, Server, ServerOptions};

fn faas_backend() -> Arc<FaasBackend> {
    let exec = Arc::new(NativeExecutor::new());
    exec.register("img/echo", |p: &[u8]| Ok(p.to_vec()));
    exec.register("img/rev", |p: &[u8]| {
        let mut v = p.to_vec();
        v.reverse();
        Ok(v)
    });
    Arc::new(FaasBackend::new(
        ResourceSpec::paper_edge("unused"),
        exec as Arc<dyn Executor>,
        Arc::new(RealClock::new()),
    ))
}

/// Both serve paths: the platform default and the portable fallback.
fn serve_paths() -> Vec<(&'static str, ServerOptions)> {
    vec![
        ("default", ServerOptions::default()),
        ("fallback", ServerOptions { force_fallback: true, ..ServerOptions::default() }),
    ]
}

#[test]
fn faas_rest_semantics_ride_one_keepalive_connection() {
    for (label, opts) in serve_paths() {
        let gw = Arc::new(FaasGateway::new(faas_backend())) as Arc<dyn Handler>;
        let server = Server::bind_with(0, 4, gw, opts).unwrap();
        let addr = server.addr();

        faas_client::deploy(&addr, "edgepwd", "echo", "img/echo", 128 << 20, 0, &[]).unwrap();
        faas_client::deploy(&addr, "edgepwd", "rev", "img/rev", 128 << 20, 0, &[]).unwrap();
        assert_eq!(faas_client::list(&addr).unwrap().len(), 2, "{label}");
        let (out, _) = faas_client::invoke(&addr, "echo", b"ping").unwrap();
        assert_eq!(out, b"ping", "{label}");

        // Binary `_batch` leg: raw non-UTF-8 payloads in one round trip.
        let calls = vec![
            BatchCall::new("echo", Bytes::from(vec![0u8, 159, 146, 150])),
            BatchCall::new("rev", Bytes::from(&b"abc"[..])),
        ];
        let results = faas_client::invoke_batch(&addr, &calls).unwrap().unwrap();
        assert_eq!(results[0].as_ref().unwrap().0, vec![0u8, 159, 146, 150], "{label}");
        assert_eq!(results[1].as_ref().unwrap().0, b"cba", "{label}");

        faas_client::remove(&addr, "edgepwd", "echo").unwrap();
        assert_eq!(faas_client::list(&addr).unwrap(), vec!["rev".to_string()], "{label}");

        // Deploys, invokes, the batch, and the listings all shared one
        // pooled keep-alive connection.
        assert_eq!(server.connections_accepted(), 1, "{label}");
    }
}

#[test]
fn connection_close_clients_see_identical_semantics() {
    for (label, opts) in serve_paths() {
        let gw = Arc::new(FaasGateway::new(faas_backend())) as Arc<dyn Handler>;
        let server = Server::bind_with(0, 4, gw, opts).unwrap();
        let addr = server.addr();
        faas_client::deploy(&addr, "edgepwd", "echo", "img/echo", 128 << 20, 0, &[]).unwrap();

        // `request_fresh` sends `Connection: close` and never pools: same
        // REST answers, one TCP connection per call.
        let before = server.connections_accepted();
        let resp = http::request_fresh(&addr, "POST", "/function/echo", &[], b"hi").unwrap();
        assert_eq!(resp.status, 200, "{label}");
        assert_eq!(resp.body, b"hi", "{label}");
        let resp = http::request_fresh(&addr, "GET", "/no/such/route", &[], &[]).unwrap();
        assert_eq!(resp.status, 404, "{label}");
        assert_eq!(server.connections_accepted(), before + 2, "{label}");
    }
}

#[test]
fn one_mib_objects_roundtrip_on_both_server_paths() {
    for (label, opts) in serve_paths() {
        let store = Arc::new(ObjectStore::new(64 << 20, "ak", "sk"));
        let gw = Arc::new(StoreGateway::new(store)) as Arc<dyn Handler>;
        let server = Server::bind_with(0, 4, gw, opts).unwrap();
        let addr = server.addr();

        let mut payload = vec![0u8; 1 << 20];
        for (i, b) in payload.iter_mut().enumerate() {
            *b = (i * 31 % 251) as u8;
        }
        store_client::make_bucket(&addr, "ak", "sk", "big").unwrap();
        store_client::put_object(&addr, "ak", "sk", "big", "blob", &payload).unwrap();
        let got = store_client::get_object(&addr, "ak", "sk", "big", "blob").unwrap();
        assert_eq!(got, payload, "{label}");
        assert_eq!(server.connections_accepted(), 1, "{label}");
    }
}

#[test]
fn sixteen_concurrent_clients_through_the_faas_gateway() {
    let gw = Arc::new(FaasGateway::new(faas_backend())) as Arc<dyn Handler>;
    let server = Server::bind(0, 8, gw).unwrap();
    let addr = server.addr();
    faas_client::deploy(&addr, "edgepwd", "echo", "img/echo", 128 << 20, 0, &[]).unwrap();

    let handles: Vec<_> = (0..16)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                for j in 0..8 {
                    let msg = format!("m{i}.{j}");
                    let (out, _) = faas_client::invoke(&addr, "echo", msg.as_bytes()).unwrap();
                    assert_eq!(out, msg.into_bytes());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // 129 requests (1 deploy + 16×8 invokes): keep-alive must collapse them
    // onto roughly one pooled connection per concurrent client, not one per
    // request. Allow slack for an occasional stale-checkout replacement.
    assert!(
        server.connections_accepted() <= 20,
        "expected ~16 pooled connections, got {}",
        server.connections_accepted()
    );
}
