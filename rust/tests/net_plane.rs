//! Network-plane integration: the real REST gateways (FaaS + object store)
//! served over the readiness-driven HTTP stack, exercised through the pooled
//! keep-alive client and through `Connection: close` clients.
//!
//! Every test that touches a server runs against both serve paths — the
//! platform default (the epoll reactor on Linux) and the forced portable
//! fallback — asserting identical REST semantics and, via
//! `Server::connections_accepted`, that keep-alive actually collapses many
//! requests onto few TCP connections.

use std::sync::Arc;
use std::time::{Duration, Instant};

use edgefaas::cluster::faas::{BatchCall, Executor, FaasBackend, NativeExecutor};
use edgefaas::cluster::gateway::{client as faas_client, FaasGateway};
use edgefaas::cluster::spec::ResourceSpec;
use edgefaas::coordinator::handle::HttpHandle;
use edgefaas::coordinator::{ResourceHandle, VerbBudgets};
use edgefaas::objstore::gateway::{client as store_client, StoreGateway};
use edgefaas::objstore::ObjectStore;
use edgefaas::simnet::RealClock;
use edgefaas::util::bytes::Bytes;
use edgefaas::util::faults::{self, FaultKind, FaultRule};
use edgefaas::util::http::{self, Handler, HttpError, RequestOptions, Server, ServerOptions};

fn faas_backend() -> Arc<FaasBackend> {
    let exec = Arc::new(NativeExecutor::new());
    exec.register("img/echo", |p: &[u8]| Ok(p.to_vec()));
    exec.register("img/rev", |p: &[u8]| {
        let mut v = p.to_vec();
        v.reverse();
        Ok(v)
    });
    Arc::new(FaasBackend::new(
        ResourceSpec::paper_edge("unused"),
        exec as Arc<dyn Executor>,
        Arc::new(RealClock::new()),
    ))
}

/// Both serve paths: the platform default and the portable fallback.
fn serve_paths() -> Vec<(&'static str, ServerOptions)> {
    vec![
        ("default", ServerOptions::default()),
        ("fallback", ServerOptions { force_fallback: true, ..ServerOptions::default() }),
    ]
}

#[test]
fn faas_rest_semantics_ride_one_keepalive_connection() {
    for (label, opts) in serve_paths() {
        let gw = Arc::new(FaasGateway::new(faas_backend())) as Arc<dyn Handler>;
        let server = Server::bind_with(0, 4, gw, opts).unwrap();
        let addr = server.addr();

        faas_client::deploy(&addr, "edgepwd", "echo", "img/echo", 128 << 20, 0, &[]).unwrap();
        faas_client::deploy(&addr, "edgepwd", "rev", "img/rev", 128 << 20, 0, &[]).unwrap();
        assert_eq!(faas_client::list(&addr).unwrap().len(), 2, "{label}");
        let (out, _) = faas_client::invoke(&addr, "echo", b"ping").unwrap();
        assert_eq!(out, b"ping", "{label}");

        // Binary `_batch` leg: raw non-UTF-8 payloads in one round trip.
        let calls = vec![
            BatchCall::new("echo", Bytes::from(vec![0u8, 159, 146, 150])),
            BatchCall::new("rev", Bytes::from(&b"abc"[..])),
        ];
        let results = faas_client::invoke_batch(&addr, &calls).unwrap().unwrap();
        assert_eq!(results[0].as_ref().unwrap().0, vec![0u8, 159, 146, 150], "{label}");
        assert_eq!(results[1].as_ref().unwrap().0, b"cba", "{label}");

        faas_client::remove(&addr, "edgepwd", "echo").unwrap();
        assert_eq!(faas_client::list(&addr).unwrap(), vec!["rev".to_string()], "{label}");

        // Deploys, invokes, the batch, and the listings all shared one
        // pooled keep-alive connection.
        assert_eq!(server.connections_accepted(), 1, "{label}");
    }
}

#[test]
fn connection_close_clients_see_identical_semantics() {
    for (label, opts) in serve_paths() {
        let gw = Arc::new(FaasGateway::new(faas_backend())) as Arc<dyn Handler>;
        let server = Server::bind_with(0, 4, gw, opts).unwrap();
        let addr = server.addr();
        faas_client::deploy(&addr, "edgepwd", "echo", "img/echo", 128 << 20, 0, &[]).unwrap();

        // `request_fresh` sends `Connection: close` and never pools: same
        // REST answers, one TCP connection per call.
        let before = server.connections_accepted();
        let resp = http::request_fresh(&addr, "POST", "/function/echo", &[], b"hi").unwrap();
        assert_eq!(resp.status, 200, "{label}");
        assert_eq!(resp.body, b"hi", "{label}");
        let resp = http::request_fresh(&addr, "GET", "/no/such/route", &[], &[]).unwrap();
        assert_eq!(resp.status, 404, "{label}");
        assert_eq!(server.connections_accepted(), before + 2, "{label}");
    }
}

#[test]
fn one_mib_objects_roundtrip_on_both_server_paths() {
    for (label, opts) in serve_paths() {
        let store = Arc::new(ObjectStore::new(64 << 20, "ak", "sk"));
        let gw = Arc::new(StoreGateway::new(store)) as Arc<dyn Handler>;
        let server = Server::bind_with(0, 4, gw, opts).unwrap();
        let addr = server.addr();

        let mut payload = vec![0u8; 1 << 20];
        for (i, b) in payload.iter_mut().enumerate() {
            *b = (i * 31 % 251) as u8;
        }
        store_client::make_bucket(&addr, "ak", "sk", "big").unwrap();
        store_client::put_object(&addr, "ak", "sk", "big", "blob", &payload).unwrap();
        let got = store_client::get_object(&addr, "ak", "sk", "big", "blob").unwrap();
        assert_eq!(got, payload, "{label}");
        assert_eq!(server.connections_accepted(), 1, "{label}");
    }
}

#[test]
fn sixteen_concurrent_clients_through_the_faas_gateway() {
    let gw = Arc::new(FaasGateway::new(faas_backend())) as Arc<dyn Handler>;
    let server = Server::bind(0, 8, gw).unwrap();
    let addr = server.addr();
    faas_client::deploy(&addr, "edgepwd", "echo", "img/echo", 128 << 20, 0, &[]).unwrap();

    let handles: Vec<_> = (0..16)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                for j in 0..8 {
                    let msg = format!("m{i}.{j}");
                    let (out, _) = faas_client::invoke(&addr, "echo", msg.as_bytes()).unwrap();
                    assert_eq!(out, msg.into_bytes());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // 129 requests (1 deploy + 16×8 invokes): keep-alive must collapse them
    // onto roughly one pooled connection per concurrent client, not one per
    // request. Allow slack for an occasional stale-checkout replacement.
    assert!(
        server.connections_accepted() <= 20,
        "expected ~16 pooled connections, got {}",
        server.connections_accepted()
    );
}

/// A raw TCP peer that answers its first request completely (keep-alive)
/// and then, on the second request over the *same* connection, writes the
/// status line plus 2 of 100 promised body bytes and stalls. The client's
/// per-request deadline — not any socket default — must bound the loss.
#[test]
fn mid_body_stall_on_a_pooled_connection_fails_at_the_deadline() {
    use std::io::{Read, Write};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let peer = std::thread::spawn(move || {
        let (mut conn, _) = listener.accept().unwrap();
        let mut read_request = |conn: &mut std::net::TcpStream| {
            let mut buf = Vec::new();
            let mut byte = [0u8; 1];
            while !buf.ends_with(b"\r\n\r\n") {
                if conn.read(&mut byte).unwrap_or(0) == 0 {
                    break;
                }
                buf.push(byte[0]);
            }
        };
        read_request(&mut conn);
        conn.write_all(b"HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\nok").unwrap();
        read_request(&mut conn);
        conn.write_all(b"HTTP/1.1 200 OK\r\nContent-Length: 100\r\n\r\nab").unwrap();
        conn.flush().unwrap();
        // Stall mid-body, connection held open, far past the deadline.
        std::thread::sleep(Duration::from_secs(3));
    });
    // First request completes and parks the connection in the pool.
    let resp = http::request_with(
        &addr,
        "GET",
        "/warm",
        &[],
        &[],
        RequestOptions::budget(Duration::from_secs(2), Duration::from_secs(5)),
    )
    .unwrap();
    assert_eq!(resp.body, b"ok");
    // Second request rides the pooled connection into the stall.
    let start = Instant::now();
    let err = http::request_with(
        &addr,
        "GET",
        "/stall",
        &[],
        &[],
        RequestOptions::budget(Duration::from_secs(2), Duration::from_millis(300)),
    )
    .unwrap_err();
    let dt = start.elapsed();
    assert!(
        matches!(HttpError::of(&err), Some(HttpError::Deadline(_))),
        "mid-body stall is a typed Deadline: {err}"
    );
    assert!(dt >= Duration::from_millis(250), "failed before the budget: {dt:?}");
    assert!(dt < Duration::from_secs(2), "budget did not bound the stall: {dt:?}");
    peer.join().unwrap();
}

/// A 10% injected error rate on the wire: idempotent verbs through an
/// [`HttpHandle`] with retries recover nearly all goodput; the same verbs
/// with retries disabled eat the raw fault rate. Deterministic per fault
/// seed.
#[test]
fn flaky_wire_goodput_recovers_with_retries_and_drops_without() {
    let _guard = faults::test_guard();
    let gw = Arc::new(FaasGateway::new(faas_backend())) as Arc<dyn Handler>;
    let server = Server::bind(0, 4, gw).unwrap();
    let addr = server.addr();
    faas_client::deploy(&addr, "edgepwd", "echo", "img/echo", 128 << 20, 0, &[]).unwrap();

    faults::injector().install(97);
    faults::injector().add_rule(
        FaultRule::new(&addr, FaultKind::ErrorRate { rate: 0.10 }).tagged("flaky-gw"),
    );
    let tight = |retry: bool| VerbBudgets {
        connect: Duration::from_secs(2),
        control: Duration::from_secs(5),
        retries: 3,
        backoff_base: Duration::from_millis(2),
        backoff_cap: Duration::from_millis(10),
        retry,
        ..VerbBudgets::default()
    };
    let with_retries =
        HttpHandle::new(addr.clone(), "edgepwd", "", "", "", "").with_budgets(tight(true));
    let without_retries =
        HttpHandle::new(addr.clone(), "edgepwd", "", "", "", "").with_budgets(tight(false));

    const CALLS: usize = 200;
    let ok_with = (0..CALLS).filter(|_| with_retries.list().is_ok()).count();
    let ok_without = (0..CALLS).filter(|_| without_retries.list().is_ok()).count();
    faults::injector().clear();

    assert!(
        ok_with >= CALLS * 9 / 10,
        "retries should hold >=90% goodput at a 10% fault rate: {ok_with}/{CALLS}"
    );
    assert!(
        ok_without < CALLS,
        "a 10% fault rate over {CALLS} calls cannot leave retry-less goodput unscathed"
    );
    assert!(
        ok_with > ok_without,
        "retries must beat no-retries: {ok_with} vs {ok_without}"
    );
}
