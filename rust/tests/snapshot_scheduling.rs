//! Integration coverage for the monitoring snapshot plane and the
//! placement decision cache (ISSUE 5): cache hits on repeated
//! `schedule_function` calls, invalidation on resource (de)registration
//! and snapshot epoch bumps, `reschedule_function` bypassing the cache,
//! staleness fallback to direct scrapes under `VirtualClock`, and the
//! clock-generic background collector.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use edgefaas::backup::DurableKv;
use edgefaas::cluster::spec::ResourceSpec;
use edgefaas::coordinator::functions::FunctionPackage;
use edgefaas::coordinator::scheduler::{FunctionCreation, LocalityScheduler, Schedule, ScheduleCtx};
use edgefaas::coordinator::{
    Affinity, AffinityType, EdgeFaaS, FunctionConfig, Reduce, Requirements, ResourceId,
    ResourceHandle,
};
use edgefaas::monitor::ResourceUsage;
use edgefaas::simnet::topology::mbps;
use edgefaas::simnet::{Clock, RealClock, Tier, Topology, VirtualClock};
use edgefaas::testbed::paper_testbed;
use edgefaas::util::bytes::Bytes;
use edgefaas::util::json::Json;

/// A phase-2 policy that counts invocations and delegates to the default.
struct SpyScheduler {
    calls: Arc<AtomicUsize>,
}

impl Schedule for SpyScheduler {
    fn schedule(
        &self,
        request: &FunctionCreation,
        ctx: &ScheduleCtx<'_>,
    ) -> anyhow::Result<Vec<ResourceId>> {
        self.calls.fetch_add(1, Ordering::SeqCst);
        LocalityScheduler.schedule(request, ctx)
    }
}

fn iot_request(anchor: ResourceId) -> FunctionCreation {
    FunctionCreation {
        app: "t".into(),
        function: FunctionConfig {
            name: "gen".into(),
            dependencies: vec![],
            requirements: Requirements::default(),
            affinity: Affinity { nodetype: Tier::Iot, affinitytype: AffinityType::Data },
            reduce: Reduce::Auto,
        },
        data_locations: vec![anchor],
        dep_locations: vec![],
    }
}

#[test]
fn decision_cache_hits_and_invalidates() {
    let b = paper_testbed(Arc::new(RealClock::new()));
    let calls = Arc::new(AtomicUsize::new(0));
    b.faas.set_scheduler(Arc::new(SpyScheduler { calls: Arc::clone(&calls) }));
    let req = iot_request(b.iot[0]);

    // At epoch 0 (nothing ever collected) decisions are live scrapes, so
    // the cache is inert: every call runs the policy.
    assert_eq!(b.faas.snapshot_epoch(), 0);
    b.faas.schedule_function(&req).unwrap();
    b.faas.schedule_function(&req).unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 2, "no memoizing without a snapshot");
    assert_eq!(b.faas.schedule_cache_stats(), (0, 0));

    // With a fresh snapshot: first call is a miss, the repeat is a pure
    // cache hit — the policy (and phase 1) never re-run, the placement is
    // identical.
    assert_eq!(b.faas.refresh_monitor_snapshot(), 1);
    let p1 = b.faas.schedule_function(&req).unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 3);
    let p2 = b.faas.schedule_function(&req).unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 3, "repeat must be served from the cache");
    assert_eq!(p1, p2);
    let (hits, misses) = b.faas.schedule_cache_stats();
    assert_eq!((hits, misses), (1, 1));

    // A snapshot epoch bump invalidates every cached decision.
    assert_eq!(b.faas.refresh_monitor_snapshot(), 2);
    b.faas.schedule_function(&req).unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 4, "epoch bump must invalidate");

    // Registering a resource invalidates; so does unregistering it.
    let donor = b.faas.resource(b.iot[0]).unwrap();
    let spec = ResourceSpec::paper_iot("pi-extra:8080");
    let extra = b.faas.register(spec, donor.handle.clone(), donor.net_node).unwrap();
    b.faas.schedule_function(&req).unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 5, "registration must invalidate");
    b.faas.unregister(extra).unwrap();
    b.faas.schedule_function(&req).unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 6, "deregistration must invalidate");

    // A different anchor set is a different key, not a hit.
    b.faas.schedule_function(&iot_request(b.iot[1])).unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 7);

    // Disabling the cache forces a policy run per call.
    b.faas.set_schedule_cache(false);
    b.faas.schedule_function(&req).unwrap();
    b.faas.schedule_function(&req).unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 9);
}

#[test]
fn reschedule_function_bypasses_the_cache() {
    let b = paper_testbed(Arc::new(RealClock::new()));
    b.executor.register("img/noop", |_: &[u8]| Ok(vec![]));
    let yaml = "\
application: mono
entrypoint: f
dag:
  - name: f
    requirements:
      memory: 1024MB
    affinity:
      nodetype: edge
      affinitytype: data
    reduce: 1
";
    let mut data = HashMap::new();
    data.insert("f".to_string(), vec![b.iot[0]]);
    let plan = b.faas.configure_application(yaml, &data).unwrap();
    assert_eq!(plan["f"], vec![b.edges[0]]);
    let pkg = FunctionPackage { code: "img/noop".into() };
    b.faas.deploy_function("mono", "f", &pkg).unwrap();
    // A fresh snapshot makes the decision cache eligible to engage.
    b.faas.refresh_monitor_snapshot();

    let calls = Arc::new(AtomicUsize::new(0));
    b.faas.set_scheduler(Arc::new(SpyScheduler { calls: Arc::clone(&calls) }));
    let (h0, m0) = b.faas.schedule_cache_stats();

    // Two identical reschedules each re-run the policy — no memoization,
    // and the cache counters do not move (bypass is neither hit nor miss).
    b.faas.reschedule_function("mono", "f", &pkg, vec![b.iot[0]]).unwrap();
    b.faas.reschedule_function("mono", "f", &pkg, vec![b.iot[0]]).unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 2, "reschedule must bypass the cache");
    assert_eq!(b.faas.schedule_cache_stats(), (h0, m0));

    // Prime the cache: a schedule_function miss, then a hit.
    let app = b.faas.app("mono").unwrap();
    let req = FunctionCreation {
        app: "mono".into(),
        function: app.config.function("f").unwrap().clone(),
        data_locations: vec![b.iot[0]],
        dep_locations: vec![],
    };
    b.faas.schedule_function(&req).unwrap();
    b.faas.schedule_function(&req).unwrap();
    assert_eq!(calls.load(Ordering::SeqCst), 3, "second call is a warm-cache hit");
    let before = calls.load(Ordering::SeqCst);
    // Load shift: saturate edge 0, republish the snapshot (so the shift is
    // visible to snapshot-backed decisions), and reschedule — the bypass
    // must observe the current monitoring data and migrate, despite the
    // warm cache still holding the pre-migration placement.
    let reg0 = b.faas.resource(b.edges[0]).unwrap();
    reg0.handle.deploy("hog", "img/noop", 127 << 29, 0, &[]).unwrap();
    reg0.handle.invoke("hog", &Bytes::new()).unwrap();
    b.faas.refresh_monitor_snapshot();
    let (old, new) = b.faas.reschedule_function("mono", "f", &pkg, vec![b.iot[0]]).unwrap();
    assert_eq!(old, vec![b.edges[0]]);
    assert_eq!(new, vec![b.edges[1]], "bypass must see the saturated edge");
    assert_eq!(calls.load(Ordering::SeqCst), before + 1);
    // The pre-migration placement is gone from the cache (migration and
    // epoch bump both invalidate): a fresh schedule recomputes.
    assert_eq!(b.faas.schedule_function(&req).unwrap(), vec![b.edges[1]]);
}

// ---------------------------------------------------------------- plane --

/// A handle whose only meaningful verb is `usage()`: fixed usage vector,
/// call counter. Scheduling never touches the other verbs.
struct CountingHandle {
    usage: ResourceUsage,
    scrapes: Arc<AtomicUsize>,
}

impl ResourceHandle for CountingHandle {
    fn deploy(
        &self,
        _name: &str,
        _image: &str,
        _memory: u64,
        _gpus: u32,
        _labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        anyhow::bail!("unused")
    }
    fn remove(&self, _name: &str) -> anyhow::Result<()> {
        anyhow::bail!("unused")
    }
    fn invoke(&self, _name: &str, _payload: &Bytes) -> anyhow::Result<(Bytes, f64)> {
        anyhow::bail!("unused")
    }
    fn list(&self) -> anyhow::Result<Vec<String>> {
        Ok(vec![])
    }
    fn describe(&self, _name: &str) -> anyhow::Result<Json> {
        anyhow::bail!("unused")
    }
    fn usage(&self) -> anyhow::Result<ResourceUsage> {
        self.scrapes.fetch_add(1, Ordering::SeqCst);
        Ok(self.usage)
    }
    fn make_bucket(&self, _bucket: &str) -> anyhow::Result<()> {
        anyhow::bail!("unused")
    }
    fn remove_bucket(&self, _bucket: &str) -> anyhow::Result<()> {
        anyhow::bail!("unused")
    }
    fn put_object(&self, _bucket: &str, _object: &str, _data: Bytes) -> anyhow::Result<()> {
        anyhow::bail!("unused")
    }
    fn get_object(&self, _bucket: &str, _object: &str) -> anyhow::Result<Bytes> {
        anyhow::bail!("unused")
    }
    fn remove_object(&self, _bucket: &str, _object: &str) -> anyhow::Result<()> {
        anyhow::bail!("unused")
    }
    fn list_objects(&self, _bucket: &str) -> anyhow::Result<Vec<String>> {
        Ok(vec![])
    }
    fn stored_bytes(&self) -> anyhow::Result<u64> {
        Ok(0)
    }
}

/// Two IoT resources on a two-node topology, every scrape counted.
fn counting_bed(clock: Arc<dyn Clock>) -> (Arc<EdgeFaaS>, Vec<ResourceId>, Arc<AtomicUsize>) {
    let mut topo = Topology::new();
    let a = topo.add_node("a", Tier::Iot);
    let b = topo.add_node("b", Tier::Iot);
    topo.add_link(a, b, 0.002, mbps(100.0));
    let faas = Arc::new(EdgeFaaS::with_parts(topo, DurableKv::ephemeral(), clock));
    let scrapes = Arc::new(AtomicUsize::new(0));
    let usage = ResourceUsage {
        cpu_frac: 0.1,
        mem_used: 1 << 30,
        mem_total: 4 << 30,
        io_bytes_per_s: 0.0,
        gpu_frac: 0.0,
        gpus_used: 0,
        gpus_total: 0,
    };
    let mut ids = Vec::new();
    for (i, node) in [a, b].into_iter().enumerate() {
        let handle = Arc::new(CountingHandle { usage, scrapes: Arc::clone(&scrapes) });
        let spec = ResourceSpec::paper_iot(&format!("pi{i}:8080"));
        ids.push(faas.register(spec, handle, node).unwrap());
    }
    (faas, ids, scrapes)
}

#[test]
fn stale_snapshot_falls_back_to_direct_scrape() {
    let clock = Arc::new(VirtualClock::new());
    let (faas, ids, scrapes) = counting_bed(clock);
    faas.set_schedule_cache(false); // count phase-1 reads per call
    let req = iot_request(ids[0]);

    // Empty snapshot (epoch 0): every decision scrapes each resource.
    faas.schedule_function(&req).unwrap();
    assert_eq!(scrapes.load(Ordering::SeqCst), 2, "per-call scrape without a snapshot");

    // One refresh scrapes everything once; decisions then read the
    // snapshot while it is within the staleness bound.
    faas.refresh_monitor_snapshot();
    assert_eq!(scrapes.load(Ordering::SeqCst), 4);
    let from_snapshot = faas.schedule_function(&req).unwrap();
    faas.schedule_function(&req).unwrap();
    assert_eq!(scrapes.load(Ordering::SeqCst), 4, "fresh snapshot: zero scrapes per decision");

    // Age the snapshot past max_age (virtual time): decisions fall back
    // to direct scrapes again.
    assert_eq!(faas.snapshot_max_age(), 5.0, "documented default");
    faas.clock().sleep(10.0);
    let from_fallback = faas.schedule_function(&req).unwrap();
    assert_eq!(scrapes.load(Ordering::SeqCst), 6, "stale snapshot: per-resource fallback");
    assert_eq!(from_snapshot, from_fallback, "same monitoring data, same placement");

    // Widening the bound makes the existing sample fresh again.
    faas.set_snapshot_max_age(100.0);
    faas.schedule_function(&req).unwrap();
    assert_eq!(scrapes.load(Ordering::SeqCst), 6);
}

#[test]
fn collector_is_clock_generic_and_stoppable() {
    // Virtual clock: the Clock::sleep-driven loop must advance virtual
    // time and publish epochs without any real blocking.
    let clock = Arc::new(VirtualClock::new());
    let (faas, _ids, _scrapes) = counting_bed(clock);
    assert!(faas.start_monitor_collector(5.0));
    assert!(!faas.start_monitor_collector(5.0), "one collector at a time");
    assert!(faas.monitor_collector_running());
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while faas.snapshot_epoch() < 3 {
        assert!(std::time::Instant::now() < deadline, "collector never published");
        std::thread::yield_now();
    }
    assert!(faas.clock().now() >= 5.0, "each cycle advances virtual time by the interval");
    let snap = faas.monitor_snapshot();
    assert_eq!(snap.len(), 2, "every registered resource sampled");
    faas.stop_monitor_collector();
    assert!(!faas.monitor_collector_running());
    // The loop re-checks the flag each cycle; after a grace period the
    // epoch must be quiescent.
    std::thread::sleep(std::time::Duration::from_millis(100));
    let e1 = faas.snapshot_epoch();
    std::thread::sleep(std::time::Duration::from_millis(100));
    assert_eq!(faas.snapshot_epoch(), e1, "stopped collector publishes nothing");
    // A new collector can start after the old one stopped.
    assert!(faas.start_monitor_collector(1.0));
    faas.stop_monitor_collector();
}

#[test]
fn collector_under_real_clock_serves_phase1_without_scrapes() {
    let (faas, ids, scrapes) = counting_bed(Arc::new(RealClock::new()));
    faas.set_schedule_cache(false);
    assert!(faas.start_monitor_collector(0.005));
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while faas.snapshot_epoch() == 0 {
        assert!(std::time::Instant::now() < deadline, "collector never published");
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    // Decisions now read the snapshot: the only scrapes are the
    // collector's own refresh cycles (2 per epoch), never 2 per decision.
    // Epoch is read before the scrape counter so a refresh racing the two
    // reads can only make the residue *under*-count collector scrapes.
    let before_epochs = faas.snapshot_epoch();
    let before = scrapes.load(Ordering::SeqCst);
    for _ in 0..50 {
        faas.schedule_function(&iot_request(ids[0])).unwrap();
    }
    // Quiesce the collector before the closing reads.
    faas.stop_monitor_collector();
    std::thread::sleep(std::time::Duration::from_millis(50));
    let epochs = (faas.snapshot_epoch() - before_epochs) as usize;
    let residue = scrapes.load(Ordering::SeqCst).saturating_sub(before + 2 * epochs);
    // 50 decisions scraping would add 100; the read race adds at most one
    // refresh cycle of noise.
    assert!(
        residue <= 2,
        "decisions must not scrape while the snapshot is fresh (residue {residue})"
    );
}
