//! Determinism of the sharded engine hot path: sharding is a pure
//! concurrency-structure change, so a mixed-QoS submission sequence must
//! produce byte-identical outputs and firing orders at every shard count —
//! `{1, 4, 16}` (1 = the old single-lock layout, 16 = fully sharded) —
//! under the wall clock, the simnet virtual clock and the discrete-event
//! `SimClock`, with per-resource invocation batching on and off, for both
//! paper workflows.
//!
//! Also the ISSUE's starvation regression at shards=16: strict priority
//! plus per-shard queues must not let a Realtime run starve 64 Batch runs
//! (work conservation via the dispatch-count aging guard), nor the Batch
//! backlog delay the Realtime run behind it.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Condvar, Mutex};

use edgefaas::coordinator::appconfig::{federated_learning_yaml, video_pipeline_yaml};
use edgefaas::coordinator::functions::FunctionPackage;
use edgefaas::coordinator::{Priority, QoS, ResourceId, RunId, WorkflowResult, ENGINE_SHARDS};
use edgefaas::simnet::{Clock, RealClock, VirtualClock};
use edgefaas::testbed::{paper_testbed, TestBed};
use edgefaas::util::json::Json;

const BUCKET: &str = "stub";

/// Deterministic stub handlers: each stage writes one object named after
/// (stage, resource, input count) whose content is the sorted basenames of
/// its inputs — outputs depend only on routing, never on timing.
fn register_stubs(bed: &TestBed, app: &'static str, stages: &[&str]) {
    for stage in stages {
        let faas = Arc::clone(&bed.faas);
        let stage_name = stage.to_string();
        bed.executor.register(&format!("img/stub-{stage}"), move |payload: &[u8]| {
            let v = edgefaas::util::json::parse(std::str::from_utf8(payload)?)?;
            let rid = v.get("resource").unwrap().as_u64().unwrap();
            let inputs: Vec<String> = v
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(|u| u.as_str().map(String::from))
                .collect();
            let mut names: Vec<String> = inputs
                .iter()
                .map(|u| u.rsplit('/').next().unwrap_or("?").to_string())
                .collect();
            names.sort();
            let obj = format!("{stage_name}-{rid}-n{}.bin", inputs.len());
            let url = faas.put_object(app, BUCKET, &obj, names.join(",").as_bytes())?;
            let mut out = Json::obj();
            out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
            Ok(out.to_string().into_bytes())
        });
    }
}

fn stub_packages(stages: &[&str]) -> HashMap<String, FunctionPackage> {
    stages
        .iter()
        .map(|s| (s.to_string(), FunctionPackage { code: format!("img/stub-{s}") }))
        .collect()
}

/// Timing-independent projection of a result: function -> per-instance
/// (resource, outputs), in placement order.
fn normalized(result: &WorkflowResult) -> BTreeMap<String, Vec<(ResourceId, Vec<String>)>> {
    result
        .functions
        .iter()
        .map(|(k, v)| (k.clone(), v.iter().map(|i| (i.resource, i.outputs.clone())).collect()))
        .collect()
}

/// The mixed-QoS submission sequence: classes cycle Batch → Interactive →
/// Realtime, with a far-future (never missed) deadline on every third run.
fn mixed_qos(i: usize) -> QoS {
    let classes = [Priority::Batch, Priority::Interactive, Priority::Realtime];
    let mut qos = QoS::class(classes[i % 3]);
    if i % 3 == 1 {
        qos = qos.with_deadline(1e6 + i as f64);
    }
    qos
}

/// Run 6 mixed-QoS runs of one workflow on a fresh paper testbed at the
/// given shard count; returns per-run (firing_order, normalized outputs)
/// in submission order.
#[allow(clippy::too_many_arguments)]
fn run_sharded(
    clock: Arc<dyn Clock>,
    yaml: &str,
    app: &'static str,
    stages: &[&str],
    data_fn: &str,
    data_of: impl Fn(&TestBed) -> Vec<ResourceId>,
    shards: usize,
    batching: bool,
) -> Vec<(Vec<String>, BTreeMap<String, Vec<(ResourceId, Vec<String>)>>)> {
    let bed = paper_testbed(clock);
    bed.faas.set_engine_shards(shards);
    assert_eq!(bed.faas.engine_shards(), shards);
    register_stubs(&bed, app, stages);
    bed.faas.set_batching(batching);
    // Tight admission (2 slots per resource) makes instances queue — the
    // regime where dispatch order and batching could diverge if sharding
    // were not transparent.
    bed.faas.set_engine_limits(8, 2);
    bed.faas.create_bucket(app, BUCKET, Some(bed.edges[0])).unwrap();
    let mut data = HashMap::new();
    data.insert(data_fn.to_string(), data_of(&bed));
    bed.faas.configure_application(yaml, &data).unwrap();
    bed.faas.deploy_application(app, &stub_packages(stages)).unwrap();
    let ids: Vec<RunId> = (0..6)
        .map(|i| bed.faas.submit_workflow_qos(app, &HashMap::new(), mixed_qos(i)).unwrap())
        .collect();
    ids.into_iter()
        .map(|id| {
            let r = bed.faas.wait_workflow(id, 120.0).unwrap();
            (r.firing_order.clone(), normalized(&r))
        })
        .collect()
}

fn assert_shard_invariant(
    yaml: &str,
    app: &'static str,
    stages: &[&str],
    data_fn: &str,
    data_of: impl Fn(&TestBed) -> Vec<ResourceId> + Copy,
) {
    assert_eq!(ENGINE_SHARDS, 16, "the sweep's top count is the physical shard count");
    for (label, clock_of) in [
        ("wall", (|| Arc::new(RealClock::new()) as Arc<dyn Clock>) as fn() -> Arc<dyn Clock>),
        ("virtual", || Arc::new(VirtualClock::new()) as Arc<dyn Clock>),
        // The discrete-event clock with no registered actors free-runs to
        // each earliest sleeper, so it drops into the same harness
        // unchanged — the suite is the SimClock/VirtualClock equivalence
        // proof on the paper workflows.
        ("sim", || Arc::new(edgefaas::simnet::SimClock::new()) as Arc<dyn Clock>),
    ] {
        for batching in [true, false] {
            let reference =
                run_sharded(clock_of(), yaml, app, stages, data_fn, data_of, 1, batching);
            for (i, (firing, _)) in reference.iter().enumerate() {
                assert_eq!(
                    firing,
                    &stages.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
                    "{app}/{label}/batching={batching}: run {i} fired out of order at shards=1"
                );
            }
            for shards in [4usize, 16] {
                let got = run_sharded(
                    clock_of(),
                    yaml,
                    app,
                    stages,
                    data_fn,
                    data_of,
                    shards,
                    batching,
                );
                assert_eq!(
                    got, reference,
                    "{app}/{label}/batching={batching}: outputs or firing orders diverged \
                     between shards=1 and shards={shards}"
                );
            }
        }
    }
}

#[test]
fn video_workflow_is_shard_count_invariant() {
    assert_shard_invariant(
        video_pipeline_yaml(),
        "videopipeline",
        &edgefaas::workflows::video::STAGES,
        "video-generator",
        |bed| vec![bed.iot[0], bed.iot[1]],
    );
}

#[test]
fn fl_workflow_is_shard_count_invariant() {
    assert_shard_invariant(
        federated_learning_yaml(),
        "federatedlearning",
        &["train", "firstaggregation", "secondaggregation"],
        "train",
        |bed| bed.iot.clone(),
    );
}

// ------------------------------------------------ starvation at shards=16

const CHAIN_YAML: &str = "\
application: chain
entrypoint: gen
dag:
  - name: gen
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
  - name: sum
    dependencies: gen
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: 1
";

/// The ISSUE's starvation regression at the full shard count: 64 Batch
/// runs plus one Realtime run, a single worker, gated handlers so queue
/// state is deterministic. The Realtime run must complete before every
/// Batch run even though its work is spread over per-resource shards, and
/// every Batch run must still complete (the aging guard keeps the class
/// work-conserving).
#[test]
fn realtime_beats_64_batch_runs_at_16_shards_and_batch_still_drains() {
    let bed = paper_testbed(Arc::new(VirtualClock::new()));
    bed.faas.set_engine_shards(16);
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    for stage in ["gen", "sum"] {
        let gate = Arc::clone(&gate);
        bed.executor.register(&format!("img/{stage}"), move |_: &[u8]| {
            let (lock, cv) = &*gate;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            Ok(br#"{"outputs":[]}"#.to_vec())
        });
    }
    let mut data = HashMap::new();
    data.insert("gen".to_string(), vec![bed.iot[0], bed.iot[1]]);
    bed.faas.configure_application(CHAIN_YAML, &data).unwrap();
    bed.faas.deploy_function("chain", "gen", &FunctionPackage { code: "img/gen".into() }).unwrap();
    bed.faas.deploy_function("chain", "sum", &FunctionPackage { code: "img/sum".into() }).unwrap();
    bed.faas.set_engine_limits(1, 8);

    let completions: Arc<Mutex<Vec<RunId>>> = Arc::new(Mutex::new(Vec::new()));
    {
        let completions = Arc::clone(&completions);
        bed.faas.on_engine_event(move |_, ev| {
            if let edgefaas::coordinator::EngineEvent::RunCompleted { run, .. } = ev {
                completions.lock().unwrap().push(*run);
            }
        });
    }

    let batch_ids: Vec<RunId> = (0..64)
        .map(|_| {
            bed.faas
                .submit_workflow_qos("chain", &HashMap::new(), QoS::class(Priority::Batch))
                .unwrap()
        })
        .collect();
    let rt = bed
        .faas
        .submit_workflow_qos("chain", &HashMap::new(), QoS::class(Priority::Realtime))
        .unwrap();
    {
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }

    bed.faas.wait_workflow(rt, 60.0).unwrap();
    for id in &batch_ids {
        bed.faas.wait_workflow(*id, 120.0).unwrap();
    }
    let order = completions.lock().unwrap();
    assert_eq!(order[0], rt, "the realtime run must complete before every batch run");
    assert_eq!(order.len(), 65, "all 64 batch runs still complete");
}
