//! Seed reproducibility of the scale harness (ISSUE 8, satellite b).
//!
//! The contract under test: a `u64` seed fully determines a population —
//! the same seed must produce a byte-identical submission schedule across
//! generator runs, and replaying that schedule through the live engine in
//! determinism mode must produce the same outcome/firing digest across
//! independent replays, across engine shard counts {1, 16}, and across
//! clock implementations (`VirtualClock` vs the discrete-event
//! `SimClock`). Different seeds must produce different schedules.
//!
//! Replays run on fresh [`scale_testbed`] beds with raised backpressure
//! and stripped deadlines ([`RunConfig::determinism`]): shed victims and
//! deadline misses are timing-dependent, so determinism is only promised
//! when nothing is shed or expired.

use std::sync::Arc;

use edgefaas::simnet::{Clock, SimActor, SimClock, VirtualClock};
use edgefaas::testbed::scale_testbed;
use edgefaas::workloads::{
    generate, install_population, run_population, schedule_digest, PopulationReport,
    PopulationSpec, RunConfig,
};

const SEED: u64 = 0x5CA1_EFAA;
const DEVICES: usize = 256;
const CELLS: usize = 4;
const DURATION_S: f64 = 20.0;

fn spec(seed: u64) -> PopulationSpec {
    PopulationSpec::standard(seed, DEVICES, CELLS, DURATION_S)
}

enum ClockKind {
    Virtual,
    Sim,
}

/// One determinism-mode replay of `seed` on a fresh bed.
fn replay(seed: u64, shards: usize, kind: ClockKind) -> PopulationReport {
    let (clock, pacer): (Arc<dyn Clock>, Option<SimActor>) = match kind {
        ClockKind::Virtual => (Arc::new(VirtualClock::new()) as Arc<dyn Clock>, None),
        ClockKind::Sim => {
            let c = Arc::new(SimClock::new());
            let actor = c.actor();
            (c as Arc<dyn Clock>, Some(actor))
        }
    };
    let bed = scale_testbed(clock, CELLS, 4);
    bed.faas.set_engine_shards(shards);
    bed.faas.set_backpressure(1_000_000, 1_000_000);
    install_population(&bed.faas, &bed.executor, &bed.cell_boxes).expect("install population");
    let schedule = generate(&spec(seed));
    assert!(!schedule.is_empty(), "population generated no submissions");
    let report = run_population(&bed.faas, &schedule, RunConfig::determinism(pacer));
    assert_eq!(report.hung, 0, "replay hung");
    assert_eq!(report.lost, 0, "replay lost run records");
    assert_eq!(
        report.completed(),
        report.submitted(),
        "determinism mode must complete every submission (nothing shed, no deadlines)"
    );
    report
}

#[test]
fn same_seed_generates_byte_identical_schedules() {
    let a = generate(&spec(SEED));
    let b = generate(&spec(SEED));
    assert_eq!(a, b, "two generator runs from the same seed must agree byte-for-byte");
    assert_eq!(schedule_digest(&a), schedule_digest(&b));
}

#[test]
fn different_seeds_generate_different_schedules() {
    let a = generate(&spec(SEED));
    let b = generate(&spec(SEED + 1));
    assert_ne!(a, b, "different seeds must not collide on the whole schedule");
    assert_ne!(schedule_digest(&a), schedule_digest(&b));
}

#[test]
fn same_seed_replays_identically_across_runs_and_shard_counts() {
    let sharded = replay(SEED, 16, ClockKind::Virtual);
    let again = replay(SEED, 16, ClockKind::Virtual);
    assert_eq!(sharded.schedule_digest, again.schedule_digest);
    assert_eq!(
        sharded.firing_digest, again.firing_digest,
        "two same-seed replays diverged in outcomes/firing orders"
    );

    let single = replay(SEED, 1, ClockKind::Virtual);
    assert_eq!(single.schedule_digest, sharded.schedule_digest);
    assert_eq!(
        single.firing_digest, sharded.firing_digest,
        "engine shard count leaked into the outcome/firing digest"
    );

    let other = replay(SEED + 1, 16, ClockKind::Virtual);
    assert_ne!(other.schedule_digest, sharded.schedule_digest);
}

#[test]
fn simclock_replay_matches_virtualclock_replay() {
    let sim = replay(SEED, 16, ClockKind::Sim);
    let virt = replay(SEED, 16, ClockKind::Virtual);
    assert_eq!(sim.schedule_digest, virt.schedule_digest);
    assert_eq!(
        sim.firing_digest, virt.firing_digest,
        "the discrete-event clock changed replay outcomes vs the atomic virtual clock"
    );
    // The paced SimClock replay advances virtual time to at least the last
    // arrival; the event-driven makespan is bounded by schedule + service.
    assert!(sim.virtual_makespan_s > 0.0);
}
