//! Equivalence of batched and unbatched execution: per-resource invocation
//! batching is a pure dispatch optimization, so a workflow run must produce
//! a byte-identical `WorkflowResult` (outputs + `firing_order`) whether the
//! engine drains same-resource batches or dispatches every instance
//! individually — under both the wall clock and the simnet virtual clock,
//! for both paper workflows, and with enough concurrent runs that the
//! batched pass actually forms multi-task batches.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use edgefaas::coordinator::appconfig::{federated_learning_yaml, video_pipeline_yaml};
use edgefaas::coordinator::functions::FunctionPackage;
use edgefaas::coordinator::{ResourceId, RunId, WorkflowResult};
use edgefaas::simnet::{Clock, RealClock, VirtualClock};
use edgefaas::testbed::{paper_testbed, TestBed};
use edgefaas::util::json::Json;

const BUCKET: &str = "stub";

/// Deterministic stub handlers: each stage writes one object named after
/// (stage, resource, input count) whose content is the sorted basenames of
/// its inputs — outputs depend only on routing, never on timing.
fn register_stubs(bed: &TestBed, app: &'static str, stages: &[&str]) {
    for stage in stages {
        let faas = Arc::clone(&bed.faas);
        let stage_name = stage.to_string();
        bed.executor.register(&format!("img/stub-{stage}"), move |payload: &[u8]| {
            let v = edgefaas::util::json::parse(std::str::from_utf8(payload)?)?;
            let rid = v.get("resource").unwrap().as_u64().unwrap();
            let inputs: Vec<String> = v
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(|u| u.as_str().map(String::from))
                .collect();
            let mut names: Vec<String> = inputs
                .iter()
                .map(|u| u.rsplit('/').next().unwrap_or("?").to_string())
                .collect();
            names.sort();
            let obj = format!("{stage_name}-{rid}-n{}.bin", inputs.len());
            let url = faas.put_object(app, BUCKET, &obj, names.join(",").as_bytes())?;
            let mut out = Json::obj();
            out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
            Ok(out.to_string().into_bytes())
        });
    }
}

fn stub_packages(stages: &[&str]) -> HashMap<String, FunctionPackage> {
    stages
        .iter()
        .map(|s| (s.to_string(), FunctionPackage { code: format!("img/stub-{s}") }))
        .collect()
}

/// Timing-independent projection of a result: function -> per-instance
/// (resource, outputs), in placement order.
fn normalized(result: &WorkflowResult) -> BTreeMap<String, Vec<(ResourceId, Vec<String>)>> {
    result
        .functions
        .iter()
        .map(|(k, v)| (k.clone(), v.iter().map(|i| (i.resource, i.outputs.clone())).collect()))
        .collect()
}

/// Run `concurrent` simultaneous stubbed workflow runs on a fresh paper
/// testbed with batching forced on or off; returns each run's result in
/// submission order.
#[allow(clippy::too_many_arguments)]
fn run_mode(
    clock: Arc<dyn Clock>,
    yaml: &str,
    app: &'static str,
    stages: &[&str],
    data_fn: &str,
    data_of: impl Fn(&TestBed) -> Vec<ResourceId>,
    batching: bool,
    concurrent: usize,
) -> Vec<WorkflowResult> {
    let bed = paper_testbed(clock);
    register_stubs(&bed, app, stages);
    bed.faas.set_batching(batching);
    // Tight admission (2 slots per resource) makes instances queue, so the
    // batched pass genuinely drains multi-task batches.
    bed.faas.set_engine_limits(8, 2);
    bed.faas.create_bucket(app, BUCKET, Some(bed.edges[0])).unwrap();
    let mut data = HashMap::new();
    data.insert(data_fn.to_string(), data_of(&bed));
    bed.faas.configure_application(yaml, &data).unwrap();
    bed.faas.deploy_application(app, &stub_packages(stages)).unwrap();
    let ids: Vec<RunId> =
        (0..concurrent).map(|_| bed.faas.submit_workflow(app, &HashMap::new()).unwrap()).collect();
    ids.into_iter().map(|id| bed.faas.wait_workflow(id, 120.0).unwrap()).collect()
}

fn assert_equivalent(
    yaml: &str,
    app: &'static str,
    stages: &[&str],
    data_fn: &str,
    data_of: impl Fn(&TestBed) -> Vec<ResourceId> + Copy,
) {
    for (label, clock_of) in [
        ("wall", (|| Arc::new(RealClock::new()) as Arc<dyn Clock>) as fn() -> Arc<dyn Clock>),
        ("virtual", || Arc::new(VirtualClock::new()) as Arc<dyn Clock>),
    ] {
        let unbatched = run_mode(clock_of(), yaml, app, stages, data_fn, data_of, false, 4);
        let batched = run_mode(clock_of(), yaml, app, stages, data_fn, data_of, true, 4);
        assert_eq!(unbatched.len(), batched.len());
        for (i, (u, b)) in unbatched.iter().zip(&batched).enumerate() {
            assert_eq!(
                u.firing_order, b.firing_order,
                "{app}/{label}: firing order diverged on run {i}"
            );
            assert_eq!(
                normalized(u),
                normalized(b),
                "{app}/{label}: outputs diverged on run {i}"
            );
        }
    }
}

#[test]
fn video_workflow_batched_equals_unbatched_under_both_clocks() {
    assert_equivalent(
        video_pipeline_yaml(),
        "videopipeline",
        &edgefaas::workflows::video::STAGES,
        "video-generator",
        |bed| vec![bed.iot[0], bed.iot[1]],
    );
}

#[test]
fn fl_workflow_batched_equals_unbatched_under_both_clocks() {
    assert_equivalent(
        federated_learning_yaml(),
        "federatedlearning",
        &["train", "firstaggregation", "secondaggregation"],
        "train",
        |bed| bed.iot.clone(),
    );
}
