//! Quickstart: register resources, configure an application from its YAML,
//! deploy a function, invoke it through the virtual function interface, and
//! use the virtual storage interface — the whole §3 API surface in ~100
//! lines.
//!
//! Run: `cargo run --release --example quickstart`

use std::collections::HashMap;
use std::sync::Arc;

use edgefaas::coordinator::functions::FunctionPackage;
use edgefaas::simnet::RealClock;
use edgefaas::testbed::paper_testbed;
use edgefaas::util::json::Json;

fn main() -> anyhow::Result<()> {
    edgefaas::util::logging::init();

    // 1. Resources. `paper_testbed` registers the paper's Fig. 4 testbed —
    //    8 Raspberry Pis, 2 edge clusters, 1 cloud cluster — each exposing
    //    FaaS + MinIO + Prometheus stand-ins behind a gateway handle.
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let faas = Arc::clone(&bed.faas);
    println!("registered resources: {:?}", faas.resource_ids());

    // 2. An application: one IoT source feeding one edge analyzer.
    let app_yaml = "\
application: quickstart
entrypoint: sense
dag:
  - name: sense
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
  - name: analyze
    dependencies: sense
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: 1
";
    // The sensor's data lives on the first two Pis.
    let mut data = HashMap::new();
    data.insert("sense".to_string(), vec![bed.iot[0], bed.iot[1]]);
    let plan = faas.configure_application(app_yaml, &data)?;
    println!("placement plan: {plan:?}");

    // 3. Function bodies (the "deployment package"): plain handlers here;
    //    see the other examples for PJRT-backed ML functions.
    {
        let faas = Arc::clone(&faas);
        bed.executor.register("img/sense", move |payload: &[u8]| {
            let v = edgefaas::util::json::parse(std::str::from_utf8(payload)?)?;
            let rid = v.req_f64("resource")? as u32;
            // Each sensor writes a reading into its local bucket.
            let url = faas.put_object(
                "quickstart",
                &format!("readings-{rid}"),
                "reading.txt",
                format!("temperature from device {rid}: 21.5C").as_bytes(),
            )?;
            let mut out = Json::obj();
            out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
            Ok(out.to_string().into_bytes())
        });
    }
    {
        let faas = Arc::clone(&faas);
        bed.executor.register("img/analyze", move |payload: &[u8]| {
            let v = edgefaas::util::json::parse(std::str::from_utf8(payload)?)?;
            let inputs = v.get("inputs").and_then(Json::as_arr).unwrap_or(&[]).to_vec();
            let mut report = String::new();
            for u in &inputs {
                let data = faas.get_object_url(u.as_str().unwrap())?;
                report.push_str(std::str::from_utf8(&data)?);
                report.push('\n');
            }
            let url = faas.put_object("quickstart", "reports", "report.txt", report.as_bytes())?;
            let mut out = Json::obj();
            out.set("outputs", Json::Arr(vec![Json::Str(url.to_string())]));
            Ok(out.to_string().into_bytes())
        });
    }

    // 4. Storage: per-device buckets (data locality) + a report bucket.
    for &rid in &[bed.iot[0], bed.iot[1]] {
        faas.create_bucket("quickstart", &format!("readings-{rid}"), Some(rid))?;
    }
    faas.create_bucket("quickstart", "reports", Some(bed.edges[0]))?;

    // 5. Deploy through the virtual function interface.
    faas.deploy_function("quickstart", "sense", &FunctionPackage { code: "img/sense".into() })?;
    faas.deploy_function("quickstart", "analyze", &FunctionPackage { code: "img/analyze".into() })?;

    // 6. Run the workflow: EdgeFaaS chains sense -> analyze, routing the
    //    readings to the single edge analyzer. `run_workflow` is the
    //    synchronous front-end over the execution engine (submit + await).
    let result = faas.run_workflow("quickstart", &HashMap::new())?;
    println!("workflow finished in {:.3}s (fired: {:?})", result.duration, result.firing_order);
    let report_url = &result.functions["analyze"][0].outputs[0];
    let report = faas.get_object_url(report_url)?;
    println!("analysis report ({report_url}):\n{}", String::from_utf8_lossy(&report));

    // 6b. The same engine serves asynchronous submissions: submit, poll,
    //     await — and N submissions interleave on the shared worker pool.
    //     Each submission carries a QoS class (and optionally a deadline):
    //     the engine's run queue dispatches Realtime before Interactive
    //     before Batch, earliest-deadline-first within a class.
    use edgefaas::coordinator::{Priority, QoS};
    let classes = [Priority::Batch, Priority::Interactive, Priority::Realtime];
    let runs: Vec<_> = classes
        .iter()
        .map(|&p| faas.submit_workflow_qos("quickstart", &HashMap::new(), QoS::class(p)))
        .collect::<Result<_, _>>()?;
    for &run in &runs {
        if let (Some(status), Some((qos, _))) = (faas.run_status(run), faas.run_qos(run)) {
            println!("run {run} [{}] status while in flight: {status:?}", qos.priority);
            break; // one peek is enough for the demo
        }
    }
    for (&run, &p) in runs.iter().zip(&classes) {
        let r = faas.wait_workflow(run, 30.0)?;
        println!("async {p} run finished in {:.3}s", r.duration);
    }

    // 7. Introspection through the same API the paper lists.
    println!("functions: {}", faas.list_functions("quickstart")?);
    println!("buckets: {:?}", faas.list_buckets("quickstart"));
    Ok(())
}
