//! End-to-end federated learning (§4.2 / §5.2): trains LeNet-5 across the
//! paper's testbed — 8 Raspberry-Pi workers, two edge aggregators, one
//! cloud aggregator — with all compute running through the AOT-compiled
//! Pallas/JAX artifacts on the PJRT runtime. Logs the loss/accuracy curve
//! per round (the repo's headline end-to-end validation; see
//! EXPERIMENTS.md §E2E).
//!
//! Run: `make artifacts && cargo run --release --example federated_learning [rounds]`

use std::collections::HashMap;
use std::sync::Arc;

use edgefaas::coordinator::appconfig::federated_learning_yaml;
use edgefaas::runtime::{EngineService, Tensor};
use edgefaas::simnet::RealClock;
use edgefaas::testbed::{artifacts_dir, paper_testbed};
use edgefaas::workflows::fedlearn;

fn main() -> anyhow::Result<()> {
    edgefaas::util::logging::init();
    let rounds: usize =
        std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(6);

    let engine = Arc::new(EngineService::start(artifacts_dir())?);
    engine.warm_up(&["lenet_train_step", "lenet_predict", "fedavg_k4", "fedavg_k2"])?;
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let faas = Arc::clone(&bed.faas);

    // Data + buckets + handlers.
    let cfg = fedlearn::FlConfig { local_steps: 4, batch: 32, lr: 0.2, shard_size: 128 };
    fedlearn::seed_shards(&faas, &bed.iot, &cfg, 42)?;
    fedlearn::create_model_buckets(&faas, &bed.all_resources())?;
    fedlearn::register_handlers(&bed.executor, Arc::clone(&engine), Arc::clone(&faas), cfg);

    // Configure + deploy exactly the paper's YAML (source code 2).
    let mut data = HashMap::new();
    data.insert("train".to_string(), bed.iot.clone());
    let plan = faas.configure_application(federated_learning_yaml(), &data)?;
    println!("deployment plan (cf. §5.2):");
    for f in ["train", "firstaggregation", "secondaggregation"] {
        println!("  {f:<18} -> resources {:?}", plan[f]);
    }
    faas.deploy_application(fedlearn::APP, &fedlearn::fl_packages())?;

    // Federated rounds.
    let mut global = fedlearn::lenet_init(7);
    let acc0 = fedlearn::evaluate(&engine, &global, 999, 4)?;
    println!("\nround  duration(s)  eval-accuracy");
    println!("  init            -  {acc0:>12.3}");
    for round in 0..rounds {
        // The aggregator "sends the shared model back to each of the edge
        // workers": place the current global model in every worker bucket.
        let urls = fedlearn::distribute_global(&faas, &bed.iot, round, &global)?;
        let mut entry = HashMap::new();
        entry.insert("train".to_string(), urls);
        // Training rounds ride the Batch QoS class: background work that
        // yields engine slots to any latency-sensitive run.
        let result = faas.run_workflow_qos(fedlearn::APP, &entry, fedlearn::default_qos())?;
        let final_url = &result.functions["secondaggregation"][0].outputs[0];
        global = Tensor::from_bytes(&faas.get_object_url(final_url)?)?;
        let acc = fedlearn::evaluate(&engine, &global, 999, 4)?;
        println!("{round:>5}  {:>11.3}  {acc:>12.3}", result.duration);
    }
    let acc_final = fedlearn::evaluate(&engine, &global, 999, 8)?;
    println!("\nfinal held-out accuracy over 256 samples: {acc_final:.3}");
    println!("(paper: the FL workflow illustrates scheduling; accuracy here validates");
    println!(" that the full three-layer stack — rust coordinator, PJRT runtime,");
    println!(" Pallas kernels — composes into working federated training.)");
    Ok(())
}
