//! Custom scheduling policies through the `Schedule` trait (§3.2.3:
//! "EdgeFaaS also offers easy to use interface for users to implement their
//! own scheduling policies").
//!
//! Implements two alternative policies — cloud-only and random-candidate —
//! plugs them into the coordinator, and compares the placements and the
//! modeled end-to-end latency of the video workflow against the default
//! locality policy (the Fig. 9 argument, made executable).
//!
//! Run: `cargo run --release --example custom_scheduler`

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use edgefaas::coordinator::appconfig::video_pipeline_yaml;
use edgefaas::coordinator::scheduler::{FunctionCreation, Schedule, ScheduleCtx};
use edgefaas::coordinator::ResourceId;
use edgefaas::perfmodel::{analytic, PaperCalib, STAGES};
use edgefaas::simnet::{RealClock, Tier};
use edgefaas::testbed::paper_testbed;
use edgefaas::util::rng::Pcg32;

/// Everything goes to the cloud (the pre-edge-computing baseline).
struct CloudOnly;
impl Schedule for CloudOnly {
    fn schedule(
        &self,
        request: &FunctionCreation,
        ctx: &ScheduleCtx<'_>,
    ) -> anyhow::Result<Vec<ResourceId>> {
        // Sources stay with their data (a camera cannot move); all compute
        // goes to the first cloud candidate.
        if request.function.dependencies.is_empty() && !request.data_locations.is_empty() {
            return Ok(request.data_locations.clone());
        }
        ctx.of_tier(Tier::Cloud)
            .first()
            .map(|r| vec![r.id])
            .ok_or_else(|| anyhow::anyhow!("no cloud resource"))
    }
}

/// Uniform-random candidate (a FaDO-style load spreader; ignores locality).
struct RandomPlacement(Mutex<Pcg32>);
impl Schedule for RandomPlacement {
    fn schedule(
        &self,
        request: &FunctionCreation,
        ctx: &ScheduleCtx<'_>,
    ) -> anyhow::Result<Vec<ResourceId>> {
        if request.function.dependencies.is_empty() && !request.data_locations.is_empty() {
            return Ok(request.data_locations.clone());
        }
        let all: Vec<ResourceId> = ctx.candidates.iter().map(|r| r.id).collect();
        anyhow::ensure!(!all.is_empty(), "no candidates");
        let mut rng = self.0.lock().unwrap();
        Ok(vec![all[rng.range(0, all.len())]])
    }
}

fn plan_with(
    policy: Option<Arc<dyn Schedule>>,
    label: &str,
) -> anyhow::Result<HashMap<String, Vec<ResourceId>>> {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    if let Some(p) = policy {
        bed.faas.set_scheduler(p);
    }
    let mut data = HashMap::new();
    data.insert("video-generator".to_string(), bed.iot[..4].to_vec());
    let plan = bed.faas.configure_application(video_pipeline_yaml(), &data)?;
    println!("\n{label}:");
    for stage in STAGES {
        let ids = &plan[stage.name()];
        let tiers: Vec<&str> = ids
            .iter()
            .map(|&r| {
                bed.faas
                    .resource(r)
                    .map(|x| x.spec.tier.name())
                    .unwrap_or("?")
            })
            .collect();
        println!("  {:<18} -> {:?} ({})", stage.name(), ids, tiers.join(","));
    }
    Ok(plan)
}

/// Modeled e2e latency of a plan: find the last edge stage (the partition
/// point) and evaluate the calibrated Fig. 9 model.
fn modeled_latency(plan: &HashMap<String, Vec<ResourceId>>, cloud: ResourceId) -> f64 {
    let calib = PaperCalib::default();
    let mut partition = 0;
    for (i, stage) in STAGES.iter().enumerate().skip(1) {
        if plan[stage.name()].iter().all(|&r| r != cloud) {
            partition = i;
        } else {
            break;
        }
    }
    analytic::end_to_end(&calib, partition)
}

fn main() -> anyhow::Result<()> {
    edgefaas::util::logging::init();
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let cloud = bed.cloud;
    drop(bed);

    let locality = plan_with(None, "default locality policy (the paper's)")?;
    let cloud_only = plan_with(Some(Arc::new(CloudOnly)), "cloud-only policy")?;
    let random =
        plan_with(Some(Arc::new(RandomPlacement(Mutex::new(Pcg32::seeded(3))))), "random policy")?;

    println!("\nmodeled end-to-end latency (calibrated Fig. 9 model):");
    println!("  locality : {:>7.2} s", modeled_latency(&locality, cloud));
    println!("  cloud-only: {:>6.2} s", modeled_latency(&cloud_only, cloud));
    println!("  random    : {:>6.2} s (depends on draw)", modeled_latency(&random, cloud));
    println!("\nthe locality policy's placement reproduces the paper's 7.4x win over");
    println!("cloud-only (Fig. 9); see `cargo bench` for the full sweep.");
    Ok(())
}
