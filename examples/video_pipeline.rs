//! End-to-end video analytics (§4.1 / §5.1): synthetic camera streams run
//! through all six stages — generation on IoT, processing + motion
//! detection (+ detection, per Fig. 10) on edge, extraction + recognition
//! on cloud — with the ML stages executing the AOT Pallas/JAX artifacts.
//! Reports per-stage placements, latencies and the recognized identities.
//!
//! Run: `make artifacts && cargo run --release --example video_pipeline [gops]`

use std::collections::HashMap;
use std::sync::Arc;

use edgefaas::coordinator::appconfig::video_pipeline_yaml;
use edgefaas::runtime::EngineService;
use edgefaas::simnet::RealClock;
use edgefaas::testbed::{artifacts_dir, paper_testbed};
use edgefaas::workflows::{common, video};

fn main() -> anyhow::Result<()> {
    edgefaas::util::logging::init();
    let gops: u64 = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(2);

    let engine = Arc::new(EngineService::start(artifacts_dir())?);
    engine.warm_up(&["motion_scores", "face_detect", "face_extract", "face_embed", "knn_classify"])?;
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let faas = Arc::clone(&bed.faas);

    video::create_buckets(&faas, &bed.all_resources())?;
    let gallery = video::enroll_gallery(&engine, 5)?;
    let cfg = video::VideoConfig { gops_per_camera: gops, ..Default::default() };
    video::register_handlers(&bed.executor, Arc::clone(&engine), Arc::clone(&faas), cfg, gallery);

    // Cameras: the first set of four Pis (Fig. 4, set 1).
    let cameras: Vec<_> = bed.iot[..4].to_vec();
    let mut data = HashMap::new();
    data.insert("video-generator".to_string(), cameras.clone());
    let plan = faas.configure_application(video_pipeline_yaml(), &data)?;
    println!("EdgeFaaS placement (cf. Fig. 10):");
    for stage in [
        "video-generator",
        "video-processing",
        "motion-detection",
        "face-detection",
        "face-extraction",
        "face-recognition",
    ] {
        let tiers: Vec<String> = plan[stage]
            .iter()
            .map(|&r| faas.resource(r).map(|x| x.spec.tier.name().to_string()).unwrap_or_default())
            .collect();
        println!("  {stage:<18} -> {:?} ({})", plan[stage], tiers.join(","));
    }

    faas.deploy_application(video::APP, &video::video_packages())?;

    let t0 = std::time::Instant::now();
    // Live video is latency-critical: submit under the Realtime QoS class
    // so the pipeline jumps any queued Interactive/Batch work.
    let result = faas.run_workflow_qos(video::APP, &HashMap::new(), video::default_qos())?;
    println!("\npipeline wall time: {:.2}s ({gops} GoPs x {} cameras)", t0.elapsed().as_secs_f64(), cameras.len());
    println!("\nper-stage instances and reported latency:");
    for stage in [
        "video-generator",
        "video-processing",
        "motion-detection",
        "face-detection",
        "face-extraction",
        "face-recognition",
    ] {
        let insts = &result.functions[stage];
        let lat: f64 = insts.iter().map(|i| i.latency).fold(0.0, f64::max);
        let outs: usize = insts.iter().map(|i| i.outputs.len()).sum();
        let n = insts.len();
        println!("  {stage:<18} {n} instance(s), max latency {lat:>7.3}s, {outs} output object(s)");
    }

    // Decode the identities the recognizer produced.
    println!("\nrecognized identities (camera rid films identity rid%10):");
    for inst in &result.functions["face-recognition"] {
        for url in &inst.outputs {
            let tensors = common::unpack_tensors(&faas.get_object_url(url)?)?;
            let labels = tensors[0].as_i32()?;
            let dists = tensors[1].as_f32()?;
            let pairs: Vec<String> = labels
                .iter()
                .zip(dists)
                .map(|(l, d)| format!("{l}({d:.2})"))
                .collect();
            println!("  {url}: {}", pairs.join(" "));
        }
    }
    Ok(())
}
