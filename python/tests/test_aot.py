"""AOT pipeline: manifest consistency and HLO-text loadability."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        aot.build(ART)
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_all_entries(manifest):
    assert set(manifest["entries"]) == set(aot._entries())


def test_artifact_files_exist_and_are_hlo_text(manifest):
    for name, e in manifest["entries"].items():
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_manifest_shapes_match_lowering_specs(manifest):
    e = manifest["entries"]["lenet_train_step"]
    assert e["inputs"][0] == {"shape": [model.LENET_PARAMS], "dtype": "f32"}
    assert e["inputs"][1] == {"shape": [aot.TRAIN_BATCH, 1, 28, 28], "dtype": "f32"}
    assert e["inputs"][2] == {"shape": [aot.TRAIN_BATCH], "dtype": "i32"}
    assert e["outputs"][0] == {"shape": [model.LENET_PARAMS], "dtype": "f32"}
    assert e["outputs"][1] == {"shape": [], "dtype": "f32"}


def test_rebuild_is_noop_when_fresh(manifest, capsys):
    did_work = aot.build(ART)
    assert not did_work, "fresh artifacts must not be rebuilt"


def test_every_artifact_has_expected_entry_signature(manifest):
    """Input/output arity in the manifest matches jax.eval_shape on the
    live entry functions — guards against manifest drift."""
    for name, (fn, specs) in aot._entries().items():
        e = manifest["entries"][name]
        assert len(e["inputs"]) == len(specs), name
        out = jax.eval_shape(fn, *specs)
        n_out = len(out) if isinstance(out, (tuple, list)) else 1
        assert len(e["outputs"]) == n_out, name


def test_lowered_hlo_declares_matching_parameters():
    """The HLO text's ENTRY parameter shapes must match the manifest —
    this is exactly the contract the rust runtime validates against."""
    path = os.path.join(ART, "fedavg_k4.hlo.txt")
    if not os.path.exists(path):
        aot.build(ART)
    with open(path) as f:
        text = f.read()
    assert "f32[4,61706]" in text, "stacked params parameter"
    assert "f32[4]" in text, "weights parameter"


def test_no_elided_constants():
    """The HLO text must never contain `constant({...})` — the target XLA
    parses elided literals as zeros (silently!). Regression guard for the
    print_large_constants option in to_hlo_text."""
    for name in os.listdir(ART):
        if not name.endswith(".hlo.txt") or name.startswith("probe_"):
            continue
        with open(os.path.join(ART, name)) as f:
            text = f.read()
        assert "{...}" not in text, f"{name} has an elided constant"
