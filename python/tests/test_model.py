"""L2 correctness: the workflow compute graphs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def synthetic_digits(rng, n):
    """Class-dependent blob images: learnable 10-class toy problem matching
    the rust-side generator's structure (see workflows/fedlearn)."""
    labels = rng.integers(0, 10, n)
    images = np.zeros((n, 1, 28, 28), np.float32)
    for i, lbl in enumerate(labels):
        ys, xs = np.mgrid[0:28, 0:28]
        cy = 6 + 2 * (lbl % 5) + rng.integers(-1, 2)
        cx = 6 + 4 * (lbl // 5) + rng.integers(-1, 2)
        images[i, 0] = np.exp(-(((ys - cy) ** 2 + (xs - cx) ** 2) / (6.0 + lbl)))
    images += rng.standard_normal(images.shape).astype(np.float32) * 0.05
    return jnp.asarray(images), jnp.asarray(labels, jnp.int32)


# ----------------------------------------------------------------- LeNet-5 --


def test_param_count_is_classic_lenet():
    assert model.LENET_PARAMS == 61706


def test_flatten_unflatten_roundtrip():
    flat = model.lenet_init(0)
    assert flat.shape == (model.LENET_PARAMS,)
    params = model.lenet_unflatten(flat)
    assert params["conv2_w"].shape == (16, 6, 5, 5)
    back = model.lenet_flatten(params)
    np.testing.assert_array_equal(flat, back)


def test_logits_shape_and_finiteness():
    flat = model.lenet_init(1)
    images = jnp.zeros((8, 1, 28, 28), jnp.float32)
    logits = model.lenet_logits(flat, images)
    assert logits.shape == (8, 10)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_log10():
    rng = np.random.default_rng(0)
    images, labels = synthetic_digits(rng, 32)
    loss = model.lenet_loss(model.lenet_init(0), images, labels)
    assert abs(float(loss) - np.log(10.0)) < 0.5


def test_train_step_reduces_loss():
    rng = np.random.default_rng(1)
    images, labels = synthetic_digits(rng, 32)
    flat = model.lenet_init(2)
    losses = []
    for _ in range(15):
        flat, loss = model.lenet_train_step_jit(flat, images, labels, jnp.float32(0.1))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, f"no learning: {losses[0]:.3f} -> {losses[-1]:.3f}"


def test_training_improves_accuracy():
    rng = np.random.default_rng(2)
    images, labels = synthetic_digits(rng, 32)
    flat = model.lenet_init(3)
    acc0 = float(model.lenet_accuracy(flat, images, labels))
    for _ in range(40):
        flat, _ = model.lenet_train_step_jit(flat, images, labels, jnp.float32(0.2))
    acc1 = float(model.lenet_accuracy(flat, images, labels))
    assert acc1 > max(acc0, 0.5), f"accuracy {acc0:.2f} -> {acc1:.2f}"


def test_predict_matches_argmax_of_logits():
    flat = model.lenet_init(4)
    rng = np.random.default_rng(3)
    images, _ = synthetic_digits(rng, 8)
    preds = model.lenet_predict(flat, images)
    logits = model.lenet_logits(flat, images)
    np.testing.assert_array_equal(preds, jnp.argmax(logits, axis=1).astype(jnp.int32))


# ------------------------------------------------------------------ FedAvg --


def test_fedavg_of_identical_models_is_identity():
    flat = model.lenet_init(5)
    stacked = jnp.stack([flat] * 4)
    avg = model.fedavg(stacked, jnp.ones(4))
    np.testing.assert_allclose(avg, flat, rtol=1e-5, atol=1e-6)


def test_two_level_aggregation_equals_flat_average():
    """Aggregating 4+4 workers per edge then 2 edges at the cloud must equal
    a flat 8-worker average when weights carry the sample counts."""
    rng = np.random.default_rng(6)
    workers = jnp.asarray(rng.standard_normal((8, 1024), dtype=np.float32))
    counts = jnp.asarray(rng.integers(10, 100, 8).astype(np.float32))
    # Flat average.
    flat_avg = model.fedavg(workers, counts)
    # Two-level: edges aggregate 4 workers each, cloud aggregates the 2
    # edge models weighted by their total counts.
    e1 = model.fedavg(workers[:4], counts[:4])
    e2 = model.fedavg(workers[4:], counts[4:])
    cloud = model.fedavg(jnp.stack([e1, e2]), jnp.asarray([counts[:4].sum(), counts[4:].sum()]))
    np.testing.assert_allclose(cloud, flat_avg, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------- video pipeline --


def synth_frame(rng, h=96, w=160, face_at=None):
    """Textured background; optionally draw the generator's face blob."""
    img = rng.random((h, w)).astype(np.float32) * 0.1
    if face_at is not None:
        cy, cx = face_at
        ys, xs = np.mgrid[0:h, 0:w].astype(np.float32)
        img += np.exp(-(((ys - cy) / 10.0) ** 2 + ((xs - cx) / 9.0) ** 2))
        for dy, dx in [(-4, -4), (-4, 4)]:
            img -= 0.8 * np.exp(-(((ys - cy - dy) ** 2 + (xs - cx - dx) ** 2) / 6.0))
    return np.clip(img, 0.0, 1.0)


def test_face_detect_prefers_frame_with_face():
    rng = np.random.default_rng(7)
    with_face = synth_frame(rng, face_at=(48, 80))
    without = synth_frame(rng)
    images = jnp.asarray(np.stack([with_face, without]))
    templates = model.face_templates()
    scores, _ = model.face_detect(images, templates)
    assert float(scores[0]) > float(scores[1]) + 0.1, f"scores={scores}"


def test_face_detect_window_localizes_face():
    rng = np.random.default_rng(8)
    img = synth_frame(rng, face_at=(48, 80))
    images = jnp.asarray(img[None])
    templates = model.face_templates()
    _, idx = model.face_detect(images, templates)
    grid_w = (160 - model.WIN) // model.STRIDE + 1
    gy, gx = int(idx[0]) // grid_w, int(idx[0]) % grid_w
    # Window top-left must be within one window of the face center.
    assert abs(gy * model.STRIDE + model.WIN // 2 - 48) <= model.WIN
    assert abs(gx * model.STRIDE + model.WIN // 2 - 80) <= model.WIN


def test_face_extract_shape_and_bounds():
    rng = np.random.default_rng(9)
    images = jnp.asarray(np.stack([synth_frame(rng) for _ in range(4)]))
    idx = jnp.asarray([0, 5, 10, 50], jnp.int32)
    patches = model.face_extract(images, idx)
    assert patches.shape == (4, model.WIN, model.WIN)
    assert bool(jnp.isfinite(patches).all())


def test_face_embed_unit_norm():
    rng = np.random.default_rng(10)
    patches = jnp.asarray(rng.random((6, 32, 32), dtype=np.float32))
    w1, w2, wd = model.embedder_params()
    emb = model.face_embed(patches, w1, w2, wd)
    assert emb.shape == (6, model.EMBED_DIM)
    np.testing.assert_allclose(jnp.linalg.norm(emb, axis=1), 1.0, rtol=1e-3)


def test_embedding_separates_identities():
    """Same-face crops must embed closer than different-face crops."""
    rng = np.random.default_rng(11)
    w1, w2, wd = model.embedder_params()

    def crop(face_seed):
        r = np.random.default_rng(face_seed)
        img = synth_frame(r, h=32, w=32, face_at=(16 + r.integers(-2, 3), 16 + r.integers(-2, 3)))
        return img

    a1, a2 = crop(100), crop(100)  # same identity, jittered
    b = crop(200)  # different identity
    emb = model.face_embed(jnp.asarray(np.stack([a1, a2, b])), w1, w2, wd)
    d_same = float(jnp.sum((emb[0] - emb[1]) ** 2))
    d_diff = float(jnp.sum((emb[0] - emb[2]) ** 2))
    assert d_same < d_diff, f"same={d_same:.4f} diff={d_diff:.4f}"


def test_knn_classify_exact_match():
    rng = np.random.default_rng(12)
    gallery = jnp.asarray(rng.standard_normal((32, 64), dtype=np.float32))
    labels = jnp.asarray(rng.integers(0, 8, 32), jnp.int32)
    # Queries = gallery rows 3 and 17: 1-NN must return their labels.
    queries = gallery[jnp.asarray([3, 17])]
    pred, dist = model.knn_classify(queries, gallery, labels)
    np.testing.assert_array_equal(pred, labels[jnp.asarray([3, 17])])
    np.testing.assert_allclose(dist, 0.0, atol=1e-3)


def test_motion_gates_pipeline():
    """GoPs without motion must score ~0 beyond the keyframe."""
    rng = np.random.default_rng(13)
    still = np.repeat(synth_frame(rng)[None], 6, axis=0)
    scores = model.motion_scores(jnp.asarray(still))
    assert float(scores[1:].max()) < 1e-5
