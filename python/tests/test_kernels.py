"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and block sizes, which must never change results)
so the kernels are validated over the whole geometry space the models use,
not just the AOT shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fedavg, knn, matmul, motion, ref

DIMS = st.integers(min_value=1, max_value=96)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ------------------------------------------------------------------ matmul --


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = matmul.matmul_pallas(a, b)
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.integers(1, 64),
    bn=st.integers(1, 64),
    bk=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_block_size_invariance(bm, bn, bk, seed):
    """Tiling is an implementation detail: results must not depend on it."""
    rng = np.random.default_rng(seed)
    a, b = rand(rng, 48, 56), rand(rng, 56, 40)
    got = matmul.matmul_pallas(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_rejects_mismatched_inner_dims():
    a = jnp.zeros((4, 5))
    b = jnp.zeros((6, 3))
    with pytest.raises(AssertionError):
        matmul.matmul_pallas(a, b)


def test_matmul_identity():
    rng = np.random.default_rng(0)
    a = rand(rng, 32, 32)
    np.testing.assert_allclose(matmul.matmul_pallas(a, jnp.eye(32)), a, rtol=1e-5, atol=1e-5)


def test_matmul_vjp_matches_ref_grads():
    rng = np.random.default_rng(3)
    a, b = rand(rng, 40, 30), rand(rng, 30, 20)

    def loss_pallas(a, b):
        return jnp.sum(matmul.matmul(a, b) ** 2)

    def loss_ref(a, b):
        return jnp.sum(ref.matmul(a, b) ** 2)

    ga = jax.grad(loss_pallas, argnums=(0, 1))(a, b)
    gr = jax.grad(loss_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga[0], gr[0], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(ga[1], gr[1], rtol=1e-3, atol=1e-3)


def test_matmul_vmem_estimate_fits_tpu_core():
    # The default 128^3 tiling must leave headroom under a 16 MiB VMEM.
    assert matmul.vmem_bytes() < (16 << 20) // 4


# ------------------------------------------------------------------ motion --


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(2, 12),
    h=st.integers(2, 48),
    w=st.integers(2, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_motion_matches_ref(t, h, w, seed):
    rng = np.random.default_rng(seed)
    frames = jnp.asarray(rng.random((t, h, w), dtype=np.float32))
    got = motion.motion_scores_pallas(frames)
    np.testing.assert_allclose(got, ref.motion_scores(frames), rtol=1e-5, atol=1e-6)


def test_motion_static_scene_scores_zero():
    frames = jnp.ones((6, 32, 32), jnp.float32) * 0.5
    scores = motion.motion_scores_pallas(frames)
    assert scores[0] == 1.0, "keyframe always flagged"
    np.testing.assert_allclose(scores[1:], 0.0, atol=1e-7)


def test_motion_detects_single_moving_block():
    frames = np.zeros((3, 32, 32), np.float32)
    frames[1, 10:20, 10:20] = 1.0  # object appears in frame 1
    frames[2] = frames[1]  # then holds still
    scores = motion.motion_scores_pallas(jnp.asarray(frames))
    assert scores[1] > 0.05
    assert scores[2] < 1e-6


@settings(max_examples=8, deadline=None)
@given(bh=st.integers(1, 48), seed=st.integers(0, 2**31 - 1))
def test_motion_block_size_invariance(bh, seed):
    rng = np.random.default_rng(seed)
    frames = jnp.asarray(rng.random((5, 48, 40), dtype=np.float32))
    got = motion.motion_scores_pallas(frames, bh=bh)
    np.testing.assert_allclose(got, ref.motion_scores(frames), rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------ fedavg --


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(1, 10),
    p=st.integers(1, 4096),
    seed=st.integers(0, 2**31 - 1),
)
def test_fedavg_matches_ref(k, p, seed):
    rng = np.random.default_rng(seed)
    stacked = rand(rng, k, p)
    weights = jnp.asarray(rng.random(k, dtype=np.float32) + 0.1)
    got = fedavg.fedavg_pallas(stacked, weights)
    np.testing.assert_allclose(got, ref.fedavg(stacked, weights), rtol=1e-4, atol=1e-5)


def test_fedavg_equal_weights_is_mean():
    rng = np.random.default_rng(1)
    stacked = rand(rng, 4, 1000)
    got = fedavg.fedavg_pallas(stacked, jnp.ones(4))
    np.testing.assert_allclose(got, stacked.mean(axis=0), rtol=1e-5, atol=1e-6)


def test_fedavg_single_worker_is_identity():
    rng = np.random.default_rng(2)
    stacked = rand(rng, 1, 512)
    got = fedavg.fedavg_pallas(stacked, jnp.asarray([3.0]))
    np.testing.assert_allclose(got, stacked[0], rtol=1e-6, atol=1e-7)


def test_fedavg_weight_normalization_invariance():
    """Scaling all weights by a constant must not change the average."""
    rng = np.random.default_rng(3)
    stacked = rand(rng, 5, 777)
    w = jnp.asarray(rng.random(5, dtype=np.float32) + 0.5)
    a = fedavg.fedavg_pallas(stacked, w)
    b = fedavg.fedavg_pallas(stacked, w * 100.0)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_fedavg_convexity_property():
    """The average must lie within the per-coordinate envelope."""
    rng = np.random.default_rng(4)
    stacked = rand(rng, 6, 2048)
    w = jnp.asarray(rng.random(6, dtype=np.float32) + 0.1)
    avg = np.asarray(fedavg.fedavg_pallas(stacked, w))
    lo, hi = np.asarray(stacked).min(0), np.asarray(stacked).max(0)
    assert (avg >= lo - 1e-5).all() and (avg <= hi + 1e-5).all()


# --------------------------------------------------------------------- knn --


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 48),
    m=st.integers(1, 48),
    d=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_l2_matches_ref(n, m, d, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, n, d), rand(rng, m, d)
    got = knn.pairwise_l2_pallas(a, b)
    np.testing.assert_allclose(got, ref.pairwise_l2(a, b), rtol=1e-3, atol=1e-3)


def test_pairwise_l2_self_distance_zero_diagonal():
    rng = np.random.default_rng(5)
    a = rand(rng, 16, 32)
    d = np.asarray(knn.pairwise_l2_pallas(a, a))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)
    assert (d >= 0).all(), "clamped at zero"


def test_pairwise_l2_known_values():
    a = jnp.asarray([[0.0, 0.0], [1.0, 1.0]])
    b = jnp.asarray([[3.0, 4.0]])
    d = knn.pairwise_l2_pallas(a, b)
    np.testing.assert_allclose(d, [[25.0], [13.0]], rtol=1e-6)
