"""Structural perf checks on the L1 kernels (the TPU side of §Perf)."""

from compile.kernels import roofline


def test_all_kernels_fit_vmem_with_double_buffer_headroom():
    # Every kernel must leave >= 2x headroom so Mosaic can double-buffer.
    for factory in roofline.ALL_PROFILES:
        p = factory()
        assert p.vmem_fraction < 0.5, f"{p.name} uses {p.vmem_fraction:.0%} of VMEM"


def test_matmul_is_compute_bound_at_512_tiling():
    p = roofline.matmul_profile(bm=512, bn=512, bk=512)
    assert p.compute_bound, f"intensity {p.intensity:.1f} < ridge {roofline.RIDGE_INTENSITY:.1f}"
    assert p.est_utilization > 0.9
    # The original 128^3 tiling is NOT compute-bound for f32 — the finding
    # that drove the L1 perf iteration (EXPERIMENTS.md §Perf).
    assert not roofline.matmul_profile(bm=128, bn=128, bk=128).compute_bound


def test_elementwise_kernels_are_bandwidth_bound():
    # Motion diff and FedAvg stream from HBM by nature; their roofline
    # position must reflect that (matching the GPU originals').
    assert not roofline.motion_profile().compute_bound
    assert not roofline.fedavg_profile().compute_bound


def test_pairwise_l2_intensity_scales_with_d():
    small = roofline.pairwise_l2_profile(d=16)
    big = roofline.pairwise_l2_profile(d=512)
    assert big.intensity > small.intensity


def test_matmul_intensity_grows_with_block_size():
    # The classic blocked-matmul result: intensity ~ block edge.
    i64 = roofline.matmul_profile(bm=64, bn=64, bk=64).intensity
    i128 = roofline.matmul_profile(bm=128, bn=128, bk=128).intensity
    i256 = roofline.matmul_profile(bm=256, bn=256, bk=256).intensity
    assert i64 < i128 < i256
    # 256^3 f32 would still fit VMEM but with less pipeline headroom.
    assert roofline.matmul_profile(bm=256, bn=256, bk=256).vmem_fraction < 0.5


def test_report_renders():
    text = roofline.report()
    assert "matmul" in text and "HBM-bound" in text


def test_default_tiling_matches_kernel_default():
    # kernels/matmul.py defaults were chosen from this analysis: keep the
    # two in sync (b/4 >= ridge => b >= 456 => 512).
    from compile.kernels import matmul
    import inspect
    sig = inspect.signature(matmul.matmul_pallas)
    b = sig.parameters["bm"].default
    assert roofline.matmul_profile(bm=b, bn=b, bk=b).compute_bound
