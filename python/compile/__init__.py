"""Build-time python: JAX models (L2) over Pallas kernels (L1), AOT-lowered
to HLO-text artifacts executed by the rust coordinator via PJRT. Never
imported at runtime."""
