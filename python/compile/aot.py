"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

Each entry in :data:`ENTRIES` is lowered once, converted to an
XlaComputation, and dumped as HLO *text* (NOT a serialized HloModuleProto:
jax >= 0.5 emits 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly — see /opt/xla-example/README.md).

A ``manifest.json`` records every artifact's input/output shapes and dtypes
so the rust runtime can validate tensors before execution.

Run via ``make artifacts`` (no-op when inputs are unchanged):

    cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fixed AOT batch geometry. The rust side pads to these shapes.
TRAIN_BATCH = 32
DETECT_BATCH = 8
GOP_FRAMES = 24
FRAME_H = 96
FRAME_W = 160
GALLERY = 32

P = model.LENET_PARAMS
f32 = jnp.float32
i32 = jnp.int32


def _spec(shape, dtype=f32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _entries():
    """entry name -> (callable, example specs)."""
    consts = model.video_constants()
    templates = consts["templates"]
    w1, w2, wd = consts["embedder"]

    return {
        # ---- federated learning ----
        "lenet_train_step": (
            model.lenet_train_step,
            [
                _spec((P,)),
                _spec((TRAIN_BATCH, 1, 28, 28)),
                _spec((TRAIN_BATCH,), i32),
                _spec(()),
            ],
        ),
        "lenet_predict": (
            model.lenet_predict,
            [_spec((P,)), _spec((TRAIN_BATCH, 1, 28, 28))],
        ),
        # Two-level aggregation (Fig. 3): 4 IoT workers per edge set, then
        # 2 edge aggregates at the cloud.
        "fedavg_k4": (
            model.fedavg,
            [_spec((4, P)), _spec((4,))],
        ),
        "fedavg_k2": (
            model.fedavg,
            [_spec((2, P)), _spec((2,))],
        ),
        # ---- video analytics ----
        "motion_scores": (
            model.motion_scores,
            [_spec((GOP_FRAMES, FRAME_H, FRAME_W))],
        ),
        "face_detect": (
            lambda images: model.face_detect(images, templates),
            [_spec((DETECT_BATCH, FRAME_H, FRAME_W))],
        ),
        "face_extract": (
            model.face_extract,
            [_spec((DETECT_BATCH, FRAME_H, FRAME_W)), _spec((DETECT_BATCH,), i32)],
        ),
        "face_embed": (
            lambda patches: model.face_embed(patches, w1, w2, wd),
            [_spec((DETECT_BATCH, model.WIN, model.WIN))],
        ),
        "knn_classify": (
            model.knn_classify,
            [
                _spec((DETECT_BATCH, model.EMBED_DIM)),
                _spec((GALLERY, model.EMBED_DIM)),
                _spec((GALLERY,), i32),
            ],
        ),
    }


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring).

    Printed with ``print_large_constants=True``: the default printer elides
    big literals as ``constant({...})``, which the target XLA's text parser
    silently reads back as zeros — the face templates / embedder weights /
    any baked model constant would vanish. (Found the hard way; covered by
    ``test_no_elided_constants``.)
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # The target parser predates `source_end_line`-style metadata: strip it.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint8": "u8"}.get(str(dt), str(dt))


def _describe(avals):
    out = []
    for a in avals:
        out.append({"shape": [int(d) for d in a.shape], "dtype": _dtype_name(a.dtype)})
    return out


def _source_fingerprint() -> str:
    """Hash of every .py under compile/ — drives the no-op rebuild check."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def build(out_dir: str, force: bool = False) -> bool:
    """Lower every entry into ``out_dir``. Returns True if work was done."""
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    fingerprint = _source_fingerprint()
    if not force and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        if old.get("fingerprint") == fingerprint and all(
            os.path.exists(os.path.join(out_dir, e["file"])) for e in old["entries"].values()
        ):
            print(f"artifacts up to date in {out_dir} (fingerprint {fingerprint[:12]})")
            return False

    manifest = {"fingerprint": fingerprint, "entries": {}}
    for name, (fn, specs) in _entries().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *specs)
        out_list = out_avals if isinstance(out_avals, (tuple, list)) else [out_avals]
        manifest["entries"][name] = {
            "file": fname,
            "inputs": _describe(specs),
            "outputs": _describe(out_list),
        }
        print(f"lowered {name}: {len(text)} chars, "
              f"{len(specs)} inputs -> {len(out_list)} outputs")

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {manifest_path}")
    return True


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true", help="rebuild even if fresh")
    args = ap.parse_args()
    build(args.out_dir, force=args.force)


if __name__ == "__main__":
    sys.exit(main())
