"""Layer-2: the EdgeFaaS workflows' compute graphs, in JAX over Pallas.

Everything the two paper workflows execute at a function's core lives here:

Video analytics (§4.1)
    * :func:`motion_scores`       — inter-frame comparison (OpenCV stand-in)
    * :func:`face_detect`         — sliding-window template correlation
                                     (SSD stand-in; windows x templates is an
                                     im2col matmul on the Pallas kernel)
    * :func:`face_embed`          — small CNN encoder (ResNet-34 stand-in)
    * :func:`knn_classify`        — 1-NN over gallery embeddings

Federated learning (§4.2)
    * LeNet-5 (LeCun et al.): :func:`lenet_init`, :func:`lenet_predict`,
      :func:`lenet_train_step` (fwd + bwd + SGD, flat parameter vector in
      and out so models cross the rust boundary as one tensor)
    * :func:`fedavg`              — weighted model averaging

All dense contractions route through the Pallas matmul
(:mod:`compile.kernels.matmul`), so the AOT-lowered HLO exercises the L1
kernels end to end. Shapes are fixed at lowering time by `aot.py`; the rust
coordinator pads batches to match.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fedavg as fedavg_kernel
from .kernels import knn as knn_kernel
from .kernels import matmul as matmul_kernel
from .kernels import motion as motion_kernel

# ----------------------------------------------------------------- LeNet-5 --

#: (name, shape) of every LeNet-5 parameter, in flat-vector order.
LENET_SHAPES = [
    ("conv1_w", (6, 1, 5, 5)),
    ("conv1_b", (6,)),
    ("conv2_w", (16, 6, 5, 5)),
    ("conv2_b", (16,)),
    ("fc1_w", (400, 120)),
    ("fc1_b", (120,)),
    ("fc2_w", (120, 84)),
    ("fc2_b", (84,)),
    ("fc3_w", (84, 10)),
    ("fc3_b", (10,)),
]

#: Total parameter count (61,706 for the classic LeNet-5).
LENET_PARAMS = int(sum(np.prod(s) for _, s in LENET_SHAPES))


def lenet_unflatten(flat):
    """Split a flat [P] vector into the LeNet parameter pytree."""
    params = {}
    off = 0
    for name, shape in LENET_SHAPES:
        size = int(np.prod(shape))
        params[name] = flat[off : off + size].reshape(shape)
        off += size
    assert off == LENET_PARAMS
    return params


def lenet_flatten(params):
    """Inverse of :func:`lenet_unflatten`."""
    return jnp.concatenate([params[name].reshape(-1) for name, _ in LENET_SHAPES])


def lenet_init(seed: int = 0):
    """He-initialized flat parameter vector."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for name, shape in LENET_SHAPES:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            parts.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = int(np.prod(shape[1:])) if len(shape) == 4 else shape[0]
            scale = jnp.sqrt(2.0 / fan_in)
            parts.append(scale * jax.random.normal(sub, shape, jnp.float32).reshape(-1))
    return jnp.concatenate([p.reshape(-1) for p in parts])


def _conv2d(x, w, b, padding):
    """NCHW conv via im2col + the Pallas matmul.

    x: [B, C, H, W], w: [O, C, kh, kw] -> [B, O, H', W'].
    """
    o, c, kh, kw = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (1, 1), padding, dimension_numbers=("NCHW", "OIHW", "NCHW")
    )  # [B, C*kh*kw, H', W']
    bsz, ck, hh, ww = patches.shape
    cols = patches.transpose(0, 2, 3, 1).reshape(bsz * hh * ww, ck)
    out = matmul_kernel.matmul(cols, w.reshape(o, ck).T)  # [B*H'*W', O]
    out = out.reshape(bsz, hh, ww, o).transpose(0, 3, 1, 2)
    return out + b[None, :, None, None]


def _avgpool2(x):
    """2x2 average pool, NCHW."""
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).mean(axis=(3, 5))


def lenet_logits(flat_params, images):
    """LeNet-5 forward pass. images: [B, 1, 28, 28] -> logits [B, 10]."""
    p = lenet_unflatten(flat_params)
    x = _conv2d(images, p["conv1_w"], p["conv1_b"], "SAME")  # [B, 6, 28, 28]
    x = jnp.tanh(x)
    x = _avgpool2(x)  # [B, 6, 14, 14]
    x = _conv2d(x, p["conv2_w"], p["conv2_b"], "VALID")  # [B, 16, 10, 10]
    x = jnp.tanh(x)
    x = _avgpool2(x)  # [B, 16, 5, 5]
    x = x.reshape(x.shape[0], 400)
    x = jnp.tanh(matmul_kernel.matmul(x, p["fc1_w"]) + p["fc1_b"])
    x = jnp.tanh(matmul_kernel.matmul(x, p["fc2_w"]) + p["fc2_b"])
    return matmul_kernel.matmul(x, p["fc3_w"]) + p["fc3_b"]


def lenet_loss(flat_params, images, labels):
    """Mean softmax cross-entropy. labels: [B] int32."""
    logits = lenet_logits(flat_params, images)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return nll.mean()


def lenet_train_step(flat_params, images, labels, lr):
    """One SGD step. Returns (new_flat_params, loss).

    This is the function each IoT `train` sandbox runs repeatedly; params
    stay flat so the rust side treats the model as a single [P] tensor.
    """
    loss, grads = jax.value_and_grad(lenet_loss)(flat_params, images, labels)
    return flat_params - lr * grads, loss


def lenet_predict(flat_params, images):
    """Predicted class per image: [B] int32."""
    return jnp.argmax(lenet_logits(flat_params, images), axis=-1).astype(jnp.int32)


def lenet_accuracy(flat_params, images, labels):
    """Mean accuracy over a batch."""
    return (lenet_predict(flat_params, images) == labels).mean()


# ------------------------------------------------------------------ FedAvg --


def fedavg(stacked, weights):
    """Weighted model average over K workers. stacked: [K, P] -> [P]."""
    return fedavg_kernel.fedavg_pallas(stacked, weights)


# -------------------------------------------------------- video: motion -----


def motion_scores(frames):
    """Per-frame motion scores for a GoP. frames: [T, H, W] -> [T]."""
    return motion_kernel.motion_scores_pallas(frames)


# -------------------------------------------------- video: face detection ---

#: Face-detection sliding window geometry.
WIN = 32
STRIDE = 16
N_TEMPLATES = 8


def face_templates(seed: int = 7):
    """The detector's correlation bank: N_TEMPLATES unit-norm [WIN, WIN]
    patterns built around the synthetic "face" blob family the video
    generator draws (bright ellipse + dark eye dots at several scales).
    A stand-in for SSD's learned filters with the same pipeline role."""
    rng = np.random.RandomState(seed)
    ys, xs = np.mgrid[0:WIN, 0:WIN].astype(np.float32)
    temps = []
    for i in range(N_TEMPLATES):
        cy, cx = WIN / 2 + rng.uniform(-3, 3), WIN / 2 + rng.uniform(-3, 3)
        ry, rx = rng.uniform(8, 13), rng.uniform(7, 11)
        face = np.exp(-(((ys - cy) / ry) ** 2 + ((xs - cx) / rx) ** 2))
        for dy, dx in [(-4, -4), (-4, 4)]:
            face -= 0.8 * np.exp(-(((ys - cy - dy) ** 2 + (xs - cx - dx) ** 2) / 6.0))
        face -= face.mean()
        face /= np.linalg.norm(face) + 1e-8
        temps.append(face)
    return jnp.asarray(np.stack(temps))  # [N_TEMPLATES, WIN, WIN]


def _windows(images):
    """Extract sliding windows. images: [B, H, W] ->
    (cols [B*nwin, WIN*WIN], nwin, grid shape)."""
    b, h, w = images.shape
    patches = jax.lax.conv_general_dilated_patches(
        images[:, None],
        (WIN, WIN),
        (STRIDE, STRIDE),
        "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # [B, WIN*WIN, gh, gw]
    _, ck, gh, gw = patches.shape
    cols = patches.transpose(0, 2, 3, 1).reshape(b * gh * gw, ck)
    return cols, gh * gw, (gh, gw)


def face_detect(images, templates):
    """Sliding-window template correlation.

    images: [B, H, W], templates: [K, WIN, WIN].
    Returns (best_score [B], best_window [B] int32): the maximum normalized
    correlation over windows and templates, and the argmax window index.
    """
    b = images.shape[0]
    cols, nwin, _ = _windows(images)
    # Zero-mean, unit-norm each window so correlation is contrast-invariant.
    cols = cols - cols.mean(axis=1, keepdims=True)
    norms = jnp.linalg.norm(cols, axis=1, keepdims=True)
    cols = cols / (norms + 1e-6)
    k = templates.shape[0]
    scores = matmul_kernel.matmul(cols, templates.reshape(k, WIN * WIN).T)  # [B*nwin, K]
    scores = scores.max(axis=1).reshape(b, nwin)
    return scores.max(axis=1), jnp.argmax(scores, axis=1).astype(jnp.int32)


def extract_window(image, window_idx, grid_w):
    """Crop the detected [WIN, WIN] patch given a window index."""
    gy = window_idx // grid_w
    gx = window_idx % grid_w
    return jax.lax.dynamic_slice(image, (gy * STRIDE, gx * STRIDE), (WIN, WIN))


def face_extract(images, window_idx):
    """Crop the best window from each image.

    images: [B, H, W], window_idx: [B] int32 -> patches [B, WIN, WIN].
    """
    _, _, w = images.shape
    grid_w = (w - WIN) // STRIDE + 1
    return jax.vmap(lambda img, wi: extract_window(img, wi, grid_w))(images, window_idx)


# -------------------------------------------------- video: face embedding ---

#: Embedding dimension of the face encoder.
EMBED_DIM = 64


def embedder_params(seed: int = 11):
    """Fixed random-projection CNN weights (the ResNet-34 encoder stand-in).

    conv 5x5 x8 /2 -> tanh -> conv 3x3 x16 /2 -> tanh -> flatten -> dense 64.
    Deterministic per seed; "pre-trained" in the paper's sense of arriving
    frozen at the function.
    """
    rng = np.random.RandomState(seed)
    w1 = rng.randn(8, 1, 5, 5).astype(np.float32) * np.sqrt(2.0 / 25)
    w2 = rng.randn(16, 8, 3, 3).astype(np.float32) * np.sqrt(2.0 / (8 * 9))
    wd = rng.randn(16 * 8 * 8, EMBED_DIM).astype(np.float32) * np.sqrt(1.0 / (16 * 64))
    return jnp.asarray(w1), jnp.asarray(w2), jnp.asarray(wd)


def face_embed(patches, w1, w2, wd):
    """Encode [B, WIN, WIN] face patches into unit-norm [B, EMBED_DIM]."""
    x = patches[:, None]  # [B, 1, 32, 32]
    x = jnp.tanh(_conv_stride2(x, w1))  # [B, 8, 16, 16]
    x = jnp.tanh(_conv_stride2(x, w2))  # [B, 16, 8, 8]
    x = x.reshape(x.shape[0], -1)
    emb = matmul_kernel.matmul(x, wd)
    return emb / (jnp.linalg.norm(emb, axis=1, keepdims=True) + 1e-6)


def _conv_stride2(x, w):
    """Stride-2 SAME conv via im2col + Pallas matmul."""
    o, c, kh, kw = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (2, 2), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW")
    )
    b, ck, hh, ww = patches.shape
    cols = patches.transpose(0, 2, 3, 1).reshape(b * hh * ww, ck)
    out = matmul_kernel.matmul(cols, w.reshape(o, ck).T)
    return out.reshape(b, hh, ww, o).transpose(0, 3, 1, 2)


# ------------------------------------------------------ video: recognition --


def knn_classify(embeddings, gallery, gallery_labels):
    """1-NN classification over the gallery.

    embeddings: [B, D], gallery: [G, D], gallery_labels: [G] int32.
    Returns (labels [B] int32, distances [B]).
    """
    d = knn_kernel.pairwise_l2_pallas(embeddings, gallery)
    idx = jnp.argmin(d, axis=1)
    return gallery_labels[idx].astype(jnp.int32), jnp.min(d, axis=1)


# ----------------------------------------------------------------- jit fns --
# Jitted entry points with the AOT-export signatures (aot.py lowers these).

lenet_train_step_jit = jax.jit(lenet_train_step)
lenet_predict_jit = jax.jit(lenet_predict)
fedavg_jit = jax.jit(fedavg)
motion_scores_jit = jax.jit(motion_scores)
face_detect_jit = jax.jit(face_detect)
face_extract_jit = jax.jit(face_extract)
face_embed_jit = jax.jit(face_embed)
knn_classify_jit = jax.jit(knn_classify)


@functools.lru_cache(maxsize=None)
def video_constants():
    """The frozen tensors baked into the video pipeline artifacts."""
    return {
        "templates": face_templates(),
        "embedder": embedder_params(),
    }
