"""Layer-1 Pallas kernels for the EdgeFaaS workflows.

Every kernel runs with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls, so interpret mode lowers the kernels to plain HLO that
any backend runs. The *structure* (BlockSpec tiling, VMEM-sized blocks, MXU-
shaped matmuls) is written for TPU; DESIGN.md §Hardware-Adaptation estimates
real-TPU efficiency from the chosen block shapes.
"""

from . import fedavg, knn, matmul, motion, ref  # noqa: F401
