"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas kernels match these references to float
tolerance. They are also the "what the GPU paper code would have computed"
baselines used when estimating kernel efficiency.
"""

import jax.numpy as jnp


def matmul(a, b):
    """Plain matrix multiply with f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def motion_scores(frames):
    """Per-frame inter-frame mean absolute difference.

    frames: [T, H, W]. Returns [T] where score[0] = 1.0 (the first frame of a
    GoP seeds the motion decision) and score[t] = mean |frames[t] -
    frames[t-1]| for t >= 1.
    """
    diffs = jnp.abs(frames[1:] - frames[:-1]).mean(axis=(1, 2))
    return jnp.concatenate([jnp.ones((1,), frames.dtype), diffs.astype(frames.dtype)])


def fedavg(stacked, weights):
    """Federated averaging (McMahan et al. 2017).

    stacked: [K, P] worker parameter vectors; weights: [K] per-worker sample
    counts (or any non-negative importance). Returns the weighted average
    [P] with weights normalized to sum 1.
    """
    w = weights / jnp.sum(weights)
    return jnp.einsum("k,kp->p", w, stacked).astype(stacked.dtype)


def pairwise_l2(a, b):
    """Squared L2 distance matrix.

    a: [N, D], b: [M, D] -> [N, M] with d[i,j] = ||a_i - b_j||^2, computed as
    ||a||^2 + ||b||^2 - 2 a.b (clamped at 0 against rounding).
    """
    a2 = jnp.sum(a * a, axis=1, keepdims=True)
    b2 = jnp.sum(b * b, axis=1, keepdims=True).T
    cross = jnp.matmul(a, b.T, preferred_element_type=jnp.float32)
    return jnp.maximum(a2 + b2 - 2.0 * cross, 0.0).astype(a.dtype)
