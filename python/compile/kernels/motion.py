"""Motion-detection Pallas kernel (the paper's OpenCV inter-frame compare).

The video workflow's motion-detection stage "uses OpenCV to do inter-frame
comparison" (§4.1) — on a GoP of T frames it computes, per frame, the mean
absolute difference against the previous frame. On GPU this is a trivial
elementwise+reduce CUDA kernel; the TPU shape is a VPU-friendly tiled
reduction:

* grid over (frame, row-block): each program reduces a ``(bh, W)`` strip of
  |frame_t - frame_{t-1}| into a partial sum — rows are the contiguous
  minor-most axis so HBM reads are sequential;
* partial sums land in a small [T, H/bh] accumulator that a cheap jnp
  epilogue folds into the per-frame means (and forces score[0] = 1.0, the
  GoP keyframe convention).

Working set per program: 2 strips of bh * W f32. For bh=16, W=320 that is
40 KiB — bandwidth-bound by design, as on GPU; the roofline is HBM BW.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _motion_kernel(cur_ref, prev_ref, o_ref):
    """Partial sum of |cur - prev| over one (bh, W) strip of one frame."""
    diff = jnp.abs(cur_ref[...] - prev_ref[...])
    o_ref[0, 0] = jnp.sum(diff, dtype=jnp.float32)


def _block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bh",))
def motion_scores_pallas(frames, bh: int = 16):
    """Per-frame motion scores for a GoP.

    frames: [T, H, W] luma in [0, 1]. Returns [T] f32: score[0] = 1.0 and
    score[t] = mean |frames[t] - frames[t-1]| for t >= 1.
    """
    t, h, w = frames.shape
    assert t >= 2, "a GoP needs at least two frames"
    bh = _block(h, bh)
    grid = (t - 1, h // bh)
    partials = pl.pallas_call(
        _motion_kernel,
        grid=grid,
        in_specs=[
            # current frame strip: frames[i+1], rows [j*bh, (j+1)*bh)
            pl.BlockSpec((1, bh, w), lambda i, j: (i + 1, j, 0)),
            # previous frame strip: frames[i]
            pl.BlockSpec((1, bh, w), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t - 1, h // bh), jnp.float32),
        interpret=True,
    )(frames, frames)
    means = partials.sum(axis=1) / jnp.float32(h * w)
    return jnp.concatenate([jnp.ones((1,), jnp.float32), means]).astype(frames.dtype)
