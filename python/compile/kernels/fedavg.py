"""FedAvg Pallas kernel (weighted model averaging, McMahan et al. 2017).

The aggregator "receives the weights from all the workers and performs
averaging on the received weights" (§4.2). For K workers and P parameters
the compute is a [K] x [K, P] weighted reduction — tiny FLOPs but, at real
model sizes, P is millions and the tensor streams from HBM, so the TPU
shape is a streaming reduction:

* grid over P/bp parameter tiles; each program keeps all K worker rows of
  its tile in VMEM (K is small — 4 or 8 edge workers) plus the [K] weight
  vector, and emits one [bp] output tile;
* working set: (K + 1) * bp f32. For K=8, bp=8192 that is 288 KiB — VMEM-
  resident with plenty of headroom for pipelining the HBM streams.

Weights are normalized inside the kernel epilogue so callers can pass raw
sample counts.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fedavg_kernel(stacked_ref, w_ref, o_ref):
    """One [bp] tile of the weighted average across K workers."""
    w = w_ref[...]
    w = w / jnp.sum(w)
    # [K, bp] * [K, 1] -> sum over K -> [bp]
    o_ref[...] = jnp.sum(stacked_ref[...] * w[:, None], axis=0, dtype=jnp.float32).astype(
        o_ref.dtype
    )


def _block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bp",))
def fedavg_pallas(stacked, weights, bp: int = 8192):
    """Weighted average of worker parameter vectors.

    stacked: [K, P], weights: [K] (raw, normalized internally) -> [P].
    """
    k, p = stacked.shape
    assert weights.shape == (k,), f"weights {weights.shape} vs K={k}"
    bp = _block(p, bp)
    return pl.pallas_call(
        _fedavg_kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((k, bp), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), stacked.dtype),
        interpret=True,
    )(stacked, weights)
