"""Static roofline / VMEM analysis of the Pallas kernels.

Interpret mode gives CPU-numpy timings, which say nothing about TPU
performance — so the perf story for L1 is *structural*: per kernel, compute
the VMEM working set per program, the FLOPs and HBM bytes per grid step,
the arithmetic intensity, and which roofline regime (MXU-compute-bound vs
HBM-bandwidth-bound) the kernel lands in on a reference TPU core.

Reference core (v4-lite-ish, used only for ratios): 16 MiB VMEM,
275 TFLOP/s bf16 MXU (~half for f32), 1.2 TB/s HBM.
"""

from dataclasses import dataclass

VMEM_BYTES = 16 << 20
PEAK_FLOPS_F32 = 137e12
PEAK_HBM = 1.2e12

#: Intensity above which an f32 kernel is compute-bound on the reference core.
RIDGE_INTENSITY = PEAK_FLOPS_F32 / PEAK_HBM  # ~114 FLOP/byte


@dataclass
class KernelProfile:
    name: str
    vmem_bytes: int
    flops_per_step: float
    hbm_bytes_per_step: float

    @property
    def intensity(self) -> float:
        return self.flops_per_step / max(self.hbm_bytes_per_step, 1.0)

    @property
    def compute_bound(self) -> bool:
        return self.intensity >= RIDGE_INTENSITY

    @property
    def vmem_fraction(self) -> float:
        return self.vmem_bytes / VMEM_BYTES

    @property
    def est_utilization(self) -> float:
        """Roofline-attainable fraction of MXU peak (f32)."""
        attainable = min(PEAK_FLOPS_F32, self.intensity * PEAK_HBM)
        return attainable / PEAK_FLOPS_F32


def matmul_profile(bm=512, bn=512, bk=512, dtype_bytes=4) -> KernelProfile:
    """One (bm, bn, bk) grid step of the tiled matmul."""
    return KernelProfile(
        name="matmul",
        vmem_bytes=dtype_bytes * (bm * bk + bk * bn) + 4 * bm * bn,
        flops_per_step=2.0 * bm * bn * bk,
        # A and B tiles stream from HBM each step; the accumulator tile is
        # VMEM-resident across the K axis (written once per (i, j)).
        hbm_bytes_per_step=dtype_bytes * (bm * bk + bk * bn),
    )


def motion_profile(bh=16, w=160, dtype_bytes=4) -> KernelProfile:
    """One (frame, row-strip) step of the motion kernel."""
    elems = bh * w
    return KernelProfile(
        name="motion",
        vmem_bytes=2 * dtype_bytes * elems + 4,
        flops_per_step=2.0 * elems,  # sub + abs (+ reduce adds ~1x)
        hbm_bytes_per_step=2.0 * dtype_bytes * elems,
    )


def fedavg_profile(k=8, bp=8192, dtype_bytes=4) -> KernelProfile:
    """One bp-wide tile of the weighted average."""
    return KernelProfile(
        name="fedavg",
        vmem_bytes=dtype_bytes * (k * bp + k + bp),
        flops_per_step=2.0 * k * bp,
        hbm_bytes_per_step=dtype_bytes * (k * bp + bp),
    )


def pairwise_l2_profile(bm=128, bn=128, d=64, dtype_bytes=4) -> KernelProfile:
    """One (bm, bn) distance tile."""
    return KernelProfile(
        name="pairwise_l2",
        vmem_bytes=dtype_bytes * (bm * d + bn * d + bm * bn),
        flops_per_step=2.0 * bm * bn * d + 2.0 * (bm + bn) * d + 3.0 * bm * bn,
        hbm_bytes_per_step=dtype_bytes * (bm * d + bn * d + bm * bn),
    )


ALL_PROFILES = [matmul_profile, motion_profile, fedavg_profile, pairwise_l2_profile]


def report() -> str:
    lines = [
        f"{'kernel':<12} {'VMEM/prog':>10} {'%VMEM':>6} {'FLOP/B':>8} "
        f"{'regime':<14} {'est. MXU util':>13}"
    ]
    for factory in ALL_PROFILES:
        p = factory()
        regime = "compute-bound" if p.compute_bound else "HBM-bound"
        lines.append(
            f"{p.name:<12} {p.vmem_bytes / 1024:>8.0f}KB {p.vmem_fraction * 100:>5.1f}% "
            f"{p.intensity:>8.1f} {regime:<14} {p.est_utilization * 100:>12.1f}%"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
