"""Pairwise squared-L2 Pallas kernel for k-NN face classification.

Face recognition "uses k-nearest neighbors (k-NN) to classify the faces"
(§4.1) over ResNet-style embeddings. The distance matrix is the hot part:
``d[i,j] = ||a_i||^2 + ||b_j||^2 - 2 a_i . b_j``. The cross term is a
matmul — exactly what the MXU wants — so the kernel computes, per (bm, bn)
output tile:

* the -2ab cross term as an MXU matmul over the full D axis (embedding
  dims are small: 64-512, so D fits in VMEM untiled);
* the row/column squared norms inline on the VPU;
* a fused clamp at zero (float rounding can drive tiny distances negative).

Working set per program: bm*D + bn*D + bm*bn f32 — for bm=bn=128, D=64
that is 128 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _l2_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]
    b = b_ref[...]
    cross = jnp.matmul(a, b.T, preferred_element_type=jnp.float32)
    a2 = jnp.sum(a * a, axis=1, dtype=jnp.float32)[:, None]
    b2 = jnp.sum(b * b, axis=1, dtype=jnp.float32)[None, :]
    o_ref[...] = jnp.maximum(a2 + b2 - 2.0 * cross, 0.0).astype(o_ref.dtype)


def _block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def pairwise_l2_pallas(a, b, bm: int = 128, bn: int = 128):
    """Squared L2 distances. a: [N, D], b: [M, D] -> [N, M]."""
    n, d = a.shape
    m, d2 = b.shape
    assert d == d2, f"dim mismatch: {a.shape} vs {b.shape}"
    bm, bn = _block(n, bm), _block(m, bn)
    return pl.pallas_call(
        _l2_kernel,
        grid=(n // bm, m // bn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), a.dtype),
        interpret=True,
    )(a, b)
