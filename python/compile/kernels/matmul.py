"""Tiled matmul Pallas kernel — the hot primitive of the whole stack.

Dense layers, im2col convolutions, face embedding and the k-NN cross term
all reduce to this kernel. The GPU paper ran these stages on an RTX 2080 Ti
with cuDNN/WMMA; the TPU rethink is a classic MXU-shaped blocked matmul:

* 3-D grid ``(M/bm, N/bn, K/bk)`` with K innermost: each output tile is
  revisited across the K steps and accumulated in place — the BlockSpec
  index maps express the HBM->VMEM schedule the CUDA code did with
  threadblocks + shared-memory staging;
* block sizes default to 512x512x512. Roofline analysis (kernels/
  roofline.py) drove this up from an initial 128^3: a square f32 block of
  edge b has arithmetic intensity b/4 FLOP/byte, and the reference core's
  ridge sits at ~114 FLOP/byte — so 128^3 (32 FLOP/B) is HBM-bound at ~28%
  of peak while 512^3 (128 FLOP/B) crosses into the compute-bound regime.
  The (A, B, f32 acc) working set at 512^3 is 3 MiB, 19% of a ~16 MiB VMEM,
  leaving double-buffer headroom; smaller problems shrink blocks to exact
  divisors automatically;
* accumulation is f32 (the out ref is f32 regardless of input dtype),
  matching MXU semantics for bf16 inputs.

A ``jax.custom_vjp`` wrapper routes the backward pass through the same
kernel (dA = dC @ B^T, dB = A^T @ dC) so the LeNet training step lowers to
Pallas end-to-end — pallas_call has no native autodiff rule.

The kernel runs ``interpret=True`` (see package docstring).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    """Accumulate one (bm, bn) f32 tile over the K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.matmul(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _block(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= want (keeps the grid exact)."""
    b = min(dim, want)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_pallas(a, b, bm: int = 512, bn: int = 512, bk: int = 512):
    """``a @ b`` via the tiled Pallas kernel.

    a: [M, K], b: [K, N] -> [M, N] in ``a.dtype`` (f32 accumulation inside).
    Any M/N/K; block sizes shrink to exact divisors so the grid tiles the
    problem exactly.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
    return out.astype(a.dtype)


@jax.custom_vjp
def matmul(a, b):
    """Differentiable Pallas matmul (backward pass is also Pallas)."""
    return matmul_pallas(a, b)


def _matmul_fwd(a, b):
    return matmul_pallas(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    return matmul_pallas(g, b.T), matmul_pallas(a.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(bm: int = 512, bn: int = 512, bk: int = 512, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency per program: A, B and f32 accumulator tiles."""
    return dtype_bytes * (bm * bk + bk * bn) + 4 * bm * bn
