//! Figure 7 — Computation Latency of each stage on each tier.
//!
//! Two series: the calibrated paper-scale model (edge Xeon vs cloud GPU,
//! anchor: face detection 0.433 s vs 0.113 s) and real measured PJRT
//! latencies of the ML stages on this machine's scaled substrate (shape
//! only — the testbed has no RTX 2080 Ti).

use std::sync::Arc;

use edgefaas::bench_harness::{measure, Stats, Table};
use edgefaas::perfmodel::{PaperCalib, Stage, STAGES};
use edgefaas::runtime::{EngineService, Tensor};
use edgefaas::testbed::artifacts_dir;
use edgefaas::workflows::video;

fn main() {
    let calib = PaperCalib::default();
    let mut t = Table::new(
        "Fig. 7: Computation Latency per tier (paper-scale model)",
        &["stage", "iot (s)", "edge (s)", "cloud/GPU (s)", "cloud speedup"],
    );
    for stage in STAGES.iter().skip(1) {
        let e = calib.compute(*stage, false);
        let c = calib.compute(*stage, true);
        t.row(&[
            stage.name().to_string(),
            format!("{:.2}", calib.iot_compute(*stage)),
            format!("{e:.3}"),
            format!("{c:.3}"),
            format!("{:.2}x", e / c),
        ]);
    }
    t.print();
    assert_eq!(calib.compute(Stage::FaceDetection, false), 0.433);
    assert_eq!(calib.compute(Stage::FaceDetection, true), 0.113);

    // Real PJRT latencies of the ML stages (scaled substrate).
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("\n(artifacts missing; run `make artifacts` for measured series)");
        return;
    }
    let engine = Arc::new(EngineService::start(dir).unwrap());
    engine
        .warm_up(&["motion_scores", "face_detect", "face_extract", "face_embed", "knn_classify"])
        .unwrap();
    let gop = video::synth_gop(1, 0, 1, true);
    let frames = Tensor::zeros(vec![video::DETECT_BATCH, video::FRAME_H, video::FRAME_W]);
    let idx = Tensor::i32(vec![video::DETECT_BATCH], vec![0; video::DETECT_BATCH]).unwrap();
    let patches = Tensor::zeros(vec![video::DETECT_BATCH, video::WIN, video::WIN]);
    let gallery = Tensor::zeros(vec![video::GALLERY, video::EMBED_DIM]);
    let glabels = Tensor::i32(vec![video::GALLERY], vec![0; video::GALLERY]).unwrap();
    let emb = Tensor::zeros(vec![video::DETECT_BATCH, video::EMBED_DIM]);
    let cases: Vec<(&str, &str, Vec<Tensor>)> = vec![
        ("motion-detection", "motion_scores", vec![gop]),
        ("face-detection", "face_detect", vec![frames.clone()]),
        ("face-extraction", "face_extract", vec![frames, idx]),
        ("face-embed (part of recognition)", "face_embed", vec![patches]),
        ("knn (part of recognition)", "knn_classify", vec![emb, gallery, glabels]),
    ];
    let mut t = Table::new(
        "Fig. 7 companion: measured PJRT latency (scaled substrate, this host)",
        &["stage", "entry", "p50", "p95"],
    );
    for (label, entry, inputs) in cases {
        let stats = measure(2, 10, || {
            engine.execute(entry, &inputs).unwrap();
        });
        t.row(&[
            label.to_string(),
            entry.to_string(),
            Stats::fmt(stats.p50),
            Stats::fmt(stats.p95),
        ]);
    }
    t.print();
}
