//! Figure 9 — End-to-end Latency at Different Breakpoints: the partition
//! sweep. Paper: best at motion-detection (11.5 s), 7.4x better than
//! cloud-only, ~5% better than edge-only.

use edgefaas::bench_harness::Table;
use edgefaas::perfmodel::{analytic, PaperCalib, STAGES};

fn main() {
    let calib = PaperCalib::default();
    let sweep = analytic::partition_sweep(&calib);
    let mut t = Table::new(
        "Fig. 9: End-to-end Latency at Different Partition Points",
        &["partition point", "ingest", "edge compute", "cross xfer", "cloud compute", "total"],
    );
    for (p, total) in &sweep {
        let (ingest, edge, cross, cloud) = analytic::breakdown(&calib, *p);
        let label = match *p {
            0 => format!("{} (cloud only)", STAGES[*p].name()),
            5 => format!("{} (edge only)", STAGES[*p].name()),
            _ => STAGES[*p].name().to_string(),
        };
        t.row(&[
            label,
            format!("{ingest:.2} s"),
            format!("{edge:.2} s"),
            format!("{cross:.2} s"),
            format!("{cloud:.2} s"),
            format!("{total:.2} s"),
        ]);
    }
    t.print();
    let (best_idx, best) = analytic::best_partition(&calib);
    let cloud_only = sweep[0].1;
    let edge_only = sweep[5].1;
    println!("\nbest partition: {} at {best:.2} s (paper: motion-detection, 11.5 s)", STAGES[best_idx].name());
    println!(
        "improvement vs cloud-only: {:.1}x (paper: 7.4x); vs edge-only: {:.1}% (paper: ~5%)",
        (cloud_only - best) / best,
        (edge_only - best) / best * 100.0
    );
    assert_eq!(best_idx, 2, "best at motion-detection");
    assert!((best - 11.5).abs() < 0.2);
    assert!(((cloud_only - best) / best - 7.4).abs() < 0.3);
}
