//! Figure 5 — Data Size Variations: output data size of each video stage.
//!
//! Two series: the calibrated 30-s-window model (paper scale) and the
//! actually-measured object sizes from the real (scaled-down) pipeline
//! substrate, which must show the same *shape* — two large early stages,
//! then a cliff after motion detection.

use edgefaas::bench_harness::Table;
use edgefaas::perfmodel::{PaperCalib, STAGES};
use edgefaas::runtime::Tensor;
use edgefaas::workflows::{common, video};

/// Measured bytes each scaled stage emits for one GoP of one camera.
fn measured_stage_bytes() -> [u64; 6] {
    let gop = video::synth_gop(1, 0, 1, true);
    let gop_bytes = common::pack_tensors(&[gop.clone()]).len() as u64;
    // processing: clamp/normalize keeps geometry -> same size.
    let proc_bytes = gop_bytes;
    // motion: DETECT_BATCH subsampled frames.
    let motion = Tensor::zeros(vec![video::DETECT_BATCH, video::FRAME_H, video::FRAME_W]);
    let motion_bytes = common::pack_tensors(&[motion.clone()]).len() as u64;
    // detection: frames + window idx + scores.
    let idx = Tensor::i32(vec![video::DETECT_BATCH], vec![0; video::DETECT_BATCH]).unwrap();
    let scores = Tensor::zeros(vec![video::DETECT_BATCH]);
    let det_bytes = common::pack_tensors(&[motion, idx, scores]).len() as u64;
    // extraction: the 32x32 crops.
    let patches = Tensor::zeros(vec![video::DETECT_BATCH, video::WIN, video::WIN]);
    let ext_bytes = common::pack_tensors(&[patches]).len() as u64;
    // recognition: labels + distances.
    let labels = Tensor::i32(vec![video::DETECT_BATCH], vec![0; video::DETECT_BATCH]).unwrap();
    let dists = Tensor::zeros(vec![video::DETECT_BATCH]);
    let rec_bytes = common::pack_tensors(&[labels, dists]).len() as u64;
    [gop_bytes, proc_bytes, motion_bytes, det_bytes, ext_bytes, rec_bytes]
}

fn main() {
    let calib = PaperCalib::default();
    let measured = measured_stage_bytes();
    let mut t = Table::new(
        "Fig. 5: Data Size Variations (output per stage)",
        &["stage", "paper-scale model", "measured (scaled run)"],
    );
    for (i, stage) in STAGES.iter().enumerate() {
        t.row(&[
            stage.name().to_string(),
            format!("{:.2} MB", calib.out_bytes[i] as f64 / 1e6),
            format!("{:.1} KB", measured[i] as f64 / 1e3),
        ]);
    }
    t.print();
    // Shape checks (what the paper's figure argues): the early stages carry
    // whole frame groups; extraction/recognition carry only crops/labels.
    // (In this scaled single-GoP run motion/detection keep all 8 sampled
    // frames — the paper's extra drop there comes from its filters
    // discarding most pictures of the 30 s stream.)
    assert!(measured[0] >= measured[1], "generator >= processing");
    assert!(measured[1] > 2 * measured[2], "processing >> motion output");
    assert!(measured[3] > 10 * measured[4], "frames >> extracted crops");
    assert!(measured[4] > measured[5], "crops > identity labels");
    println!("\nshape check OK: data-heavy early stages, cliff after the frame-carrying stages");
}
