//! Figure 10 — EdgeFaaS Scheduling of the Video Analytics Workflow: the
//! placement the *actual coordinator* chooses for the paper's YAML over the
//! Fig. 4 testbed. Paper: generator on IoT; processing, motion detection,
//! face detection on edge; extraction + recognition on cloud.
//!
//! (Note: the paper's source-code-1 YAML puts face-detection on cloud while
//! its Fig. 10 and the Fig. 9 optimum put it on edge; we reproduce the
//! Fig. 10 placement — see DESIGN.md.)

use std::collections::HashMap;
use std::sync::Arc;

use edgefaas::bench_harness::{measure, Stats, Table};
use edgefaas::coordinator::appconfig::video_pipeline_yaml;
use edgefaas::simnet::RealClock;
use edgefaas::testbed::paper_testbed;

fn main() {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let faas = Arc::clone(&bed.faas);
    let mut data = HashMap::new();
    data.insert("video-generator".to_string(), bed.iot[..4].to_vec());
    let plan = faas.configure_application(video_pipeline_yaml(), &data).unwrap();

    let expected = [
        ("video-generator", "iot"),
        ("video-processing", "edge"),
        ("motion-detection", "edge"),
        ("face-detection", "edge"),
        ("face-extraction", "cloud"),
        ("face-recognition", "cloud"),
    ];
    let mut t = Table::new(
        "Fig. 10: EdgeFaaS scheduling of the video workflow",
        &["stage", "paper tier", "EdgeFaaS placement", "tier", "match"],
    );
    for (stage, paper_tier) in expected {
        let ids = &plan[stage];
        let tiers: Vec<&str> = ids
            .iter()
            .map(|&r| faas.resource(r).map(|x| x.spec.tier.name()).unwrap_or("?"))
            .collect();
        let ok = tiers.iter().all(|t| *t == paper_tier);
        t.row(&[
            stage.to_string(),
            paper_tier.to_string(),
            format!("{ids:?}"),
            tiers.join(","),
            if ok { "yes".into() } else { "NO".into() },
        ]);
        assert!(ok, "{stage} expected {paper_tier}, got {tiers:?}");
    }
    t.print();

    // How fast is configuration itself (scheduling all 6 functions)?
    let stats = measure(3, 20, || {
        let bed = paper_testbed(Arc::new(RealClock::new()));
        let mut data = HashMap::new();
        data.insert("video-generator".to_string(), bed.iot[..4].to_vec());
        bed.faas.configure_application(video_pipeline_yaml(), &data).unwrap();
    });
    println!(
        "\nconfigure_application (testbed build + 6-function two-phase schedule): p50 {}",
        Stats::fmt(stats.p50)
    );
}
