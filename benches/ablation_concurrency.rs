//! Ablation — workflow concurrency and dispatch overhead through the
//! execution engine.
//!
//! Ten sections:
//!
//! 1. **Wall clock**: throughput of 1 / 4 / 16 / 64 concurrent runs of a
//!    two-stage workflow (2 IoT generators -> 1 edge reducer) whose stages
//!    really sleep 5 ms. The engine interleaves runs on its shared worker
//!    pool under per-resource admission limits, so throughput rises until
//!    the per-stage compute saturates the pool.
//! 2. **Virtual clock**: the identical code under the simnet
//!    `VirtualClock` — the batch completes in wall-clock time that is pure
//!    engine overhead (no real sleeping), demonstrating clock-genericity.
//! 3. **Hot path (batched vs unbatched)**: zero-work handlers under the
//!    virtual clock, so wall time measures nothing but dispatch overhead.
//!    The same binary runs both series — per-resource invocation batching
//!    off, then on — at each concurrency level, plus a p50/p95 per-run
//!    dispatch-overhead measurement. Everything is written to
//!    `BENCH_hotpath.json` (override the path with `BENCH_OUT`) so future
//!    PRs have a machine-readable perf trajectory to beat.
//!
//! 4. **Mixed QoS (priority isolation)**: Realtime run latency, unloaded
//!    vs. with 64 Batch-class runs in flight, on the same zero-work
//!    hot-path bed. The QoS run queue dispatches Realtime ahead of the
//!    Batch backlog, so the loaded p95 must stay within 2x the unloaded
//!    p95 — the number a FIFO queue fails by an order of magnitude.
//!    Written to `BENCH_qos.json` (override with `BENCH_QOS_OUT`).
//!
//! 5. **Lock contention (shard sweep)**: the same zero-work bed at engine
//!    shard counts {1, 4, 16} — 1 collapses the dispatch queues and run
//!    table to the old single-lock layout, 16 gives every resource its
//!    own queue and spreads runs over 16 run shards. Runs/sec at 64 and
//!    256 concurrent runs plus per-run dispatch p50/p95 per shard count,
//!    written to `BENCH_contention.json` (override with
//!    `BENCH_CONTENTION_OUT`). Non-smoke asserts >= 1.5x runs/sec at 64
//!    concurrent runs for shards=16 over the shards=1 baseline.
//!
//! 6. **Control plane (schedule rate)**: `schedule_function` calls/sec and
//!    per-call p50/p95 at 16/64/256 registered resources, three modes on
//!    one bed — per-call `/metrics` scrape (the pre-snapshot baseline,
//!    every decision does O(resources) loopback-HTTP scrapes), the
//!    monitoring snapshot plane (decisions are pure in-memory reads), and
//!    the placement decision cache on top. Written to
//!    `BENCH_schedule.json` (override with `BENCH_SCHEDULE_OUT`).
//!    Non-smoke asserts >= 5x snapshot-vs-scrape calls/sec at 64
//!    resources.
//!
//! 7. **Network plane (keep-alive + epoll)**: echo-request throughput and
//!    per-request p50/p95 at 1/16/64 concurrent clients in three modes —
//!    (a) fresh connection per request against the thread-per-connection
//!    fallback server (the pre-refactor behaviour), (b) the pooled
//!    keep-alive client against the same fallback server, (c) the pooled
//!    client against the platform-default server (the epoll reactor on
//!    Linux) — plus a 1 MiB object PUT/GET series through the store
//!    gateway for the zero-copy body path. Written to `BENCH_net.json`
//!    (override with `BENCH_NET_OUT`). Non-smoke on Linux asserts >= 2x
//!    requests/sec for pooled+epoll over the fresh-connection baseline at
//!    64 clients.
//!
//! 8. **Liveness plane (churn)**: a fan-out app anchored at every one of
//!    16/64 one-box IoT resources under the virtual clock; one resource is
//!    killed and the bench walks monitor sweeps until the lease detector
//!    marks it Dead. Reports time-to-detect (virtual seconds from kill to
//!    the Died transition), the wall cost of the detecting sweep (drain +
//!    relocation ride inside it), MTTR (virtual seconds from kill to the
//!    first successful run on the survivors), and time-to-readmit after
//!    the resource revives (quarantine sweeps). A steady-state series runs
//!    the zero-work hot path with a 2 ms monitor sweeper alongside: lease
//!    bookkeeping must keep >= 95% of the sweeper-free throughput
//!    (asserted non-smoke). Written to `BENCH_liveness.json` (override
//!    with `BENCH_LIVENESS_OUT`).
//!
//! 9. **Fault plane (goodput under wire faults)**: a 16-resource bed where
//!    every resource is a real HTTP pair (FaaS gateway + metrics exporter)
//!    behind an `HttpHandle`, and the seeded fault injector resets a
//!    configurable fraction of requests on the wire. Goodput (fraction of
//!    16-instance runs completing) and per-run p50/p99 at fault rates
//!    0/1/5/10%, with the handle's budgeted retries on vs off — plus
//!    time-to-Suspect for a fully black-holed resource, detected from live
//!    traffic (data-path lease evidence) vs by the periodic sweeper alone.
//!    Written to `BENCH_faults.json` (override with `BENCH_FAULTS_OUT`).
//!    Non-smoke asserts >= 90% goodput at a 5% fault rate with retries on,
//!    and that data-path detection beats the sweep interval.
//!
//! 10. **Federation plane (multi-coordinator scaling)**: 1/2/4 coordinators
//!    jointly serving one shared 64-resource fleet (9 cells x 6 boxes +
//!    hubs + cloud), every coordinator behind a real REST gateway and
//!    reaching its peers only through those sockets. Sustained synchronous
//!    Realtime submissions are routed to each app's hash-owner while
//!    background drivers gossip snapshots and poll for steals; reports
//!    submissions/sec, per-run p50/p99, and gossip staleness per member
//!    count, then a skewed-load round where every submission is forwarded
//!    through an idle coordinator to a one-worker owner, whose queue the
//!    idle peer must steal over the wire (steal hit rate, loan settlement).
//!    Execution-counting handlers on the shared backends make duplicate or
//!    lost executions observable no matter which coordinator dispatched.
//!    Written to `BENCH_federation.json` (override with
//!    `BENCH_FEDERATION_OUT`). Non-smoke asserts >= 1.8x submissions/sec at
//!    4 coordinators vs 1, stolen instances > 0 under skewed load, and
//!    exactly-expected execution counts everywhere (zero duplicates).
//!
//! `ABLATION_SMOKE=1` runs a tiny-N smoke pass (CI): only the hot-path,
//! mixed-QoS, contention, control-plane, network, liveness, fault-plane
//! and federation sections, no throughput assertions, but all eight JSON
//! artifacts are still produced.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use edgefaas::backup::DurableKv;
use edgefaas::bench_harness::{measure, Stats, Table};
use edgefaas::cluster::faas::{BatchCall, Executor, FaasBackend, NativeExecutor};
use edgefaas::cluster::gateway::FaasGateway;
use edgefaas::cluster::spec::ResourceSpec;
use edgefaas::coordinator::functions::FunctionPackage;
use edgefaas::coordinator::gateway::EdgeFaasGateway;
use edgefaas::coordinator::handle::HttpHandle;
use edgefaas::coordinator::scheduler::FunctionCreation;
use edgefaas::coordinator::{
    Affinity, AffinityType, EdgeFaaS, Federation, FederationConfig, FunctionConfig, LocalHandle,
    Priority, QoS, Reduce, Requirements, ResourceHandle, ResourceId, RunId, VerbBudgets,
    ENGINE_SHARDS,
};
use edgefaas::monitor::scrape::MetricsGateway;
use edgefaas::monitor::{LeaseState, MetricsRegistry, ResourceUsage};
use edgefaas::objstore::gateway::{client as store_client, StoreGateway};
use edgefaas::objstore::ObjectStore;
use edgefaas::simnet::topology::mbps;
use edgefaas::simnet::{Clock, RealClock, Tier, Topology, VirtualClock};
use edgefaas::testbed::{federated_testbed, paper_testbed, FederatedBed, TestBed};
use edgefaas::util::bytes::Bytes;
use edgefaas::util::faults::{self, FaultKind, FaultRule};
use edgefaas::util::http::{
    self as http, Handler as HttpHandler, Request as HttpRequest, Response as HttpResponse,
    Server as HttpServer, ServerOptions,
};
use edgefaas::util::json::Json;

/// Per-instance modeled compute, seconds (sections 1-2).
const STAGE_S: f64 = 0.005;

const CHAIN_YAML: &str = "\
application: chain
entrypoint: gen
dag:
  - name: gen
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
  - name: sum
    dependencies: gen
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: 1
";

fn configure_chain(bed: &TestBed) {
    let mut data = HashMap::new();
    data.insert("gen".to_string(), vec![bed.iot[0], bed.iot[1]]);
    bed.faas.configure_application(CHAIN_YAML, &data).unwrap();
    bed.faas.deploy_function("chain", "gen", &FunctionPackage { code: "img/gen".into() }).unwrap();
    bed.faas.deploy_function("chain", "sum", &FunctionPackage { code: "img/sum".into() }).unwrap();
}

/// Sections 1-2: stages that sleep (really or virtually) for `STAGE_S`.
fn bed_with_sleeping_chain(clock: Arc<dyn Clock>) -> TestBed {
    let bed = paper_testbed(clock);
    for stage in ["gen", "sum"] {
        let clock = Arc::clone(bed.faas.clock());
        bed.executor.register(&format!("img/{stage}"), move |_: &[u8]| {
            clock.sleep(STAGE_S); // real sleep or virtual advance
            let mut out = Json::obj();
            out.set("outputs", Json::Arr(vec![]));
            Ok(out.to_string().into_bytes())
        });
    }
    configure_chain(&bed);
    bed
}

/// Section 3: zero-work, zero-allocation handlers — every invocation
/// returns a refcount bump on one shared response buffer, so the measured
/// wall time is the engine's dispatch overhead and nothing else.
fn bed_with_hotpath_chain() -> TestBed {
    bed_with_hotpath_chain_sharded(ENGINE_SHARDS)
}

/// Section 5: the same zero-work bed at an explicit engine shard count
/// (1 = the single-lock baseline layout).
fn bed_with_hotpath_chain_sharded(shards: usize) -> TestBed {
    let bed = paper_testbed(Arc::new(VirtualClock::new()));
    bed.faas.set_engine_shards(shards);
    let response = Bytes::from(r#"{"outputs":[]}"#);
    for stage in ["gen", "sum"] {
        let response = response.clone();
        bed.executor
            .register_bytes(&format!("img/{stage}"), move |_: &Bytes| Ok(response.clone()));
    }
    configure_chain(&bed);
    // Tight per-resource admission (2 slots) so instances actually queue at
    // high concurrency: that is the regime batching targets, and it loads
    // the unbatched path with the defer/wake churn a saturated router sees.
    bed.faas.set_engine_limits(16, 2);
    bed
}

/// Submit `n` runs, then await them all; returns (batch wall seconds, mean
/// per-run reported duration).
fn run_batch(bed: &TestBed, n: usize) -> (f64, f64) {
    let t0 = std::time::Instant::now();
    let ids: Vec<RunId> =
        (0..n).map(|_| bed.faas.submit_workflow("chain", &HashMap::new()).unwrap()).collect();
    let mut durations = Vec::new();
    for id in ids {
        let r = bed.faas.wait_workflow(id, 120.0).unwrap();
        durations.push(r.duration);
    }
    let wall = t0.elapsed().as_secs_f64();
    (wall, durations.iter().sum::<f64>() / n as f64)
}

/// One hot-path series: best-of-`reps` runs/sec at each level with batching
/// forced on or off. Returns (concurrency, wall, runs_per_s) rows.
fn hotpath_series(
    bed: &TestBed,
    batching: bool,
    levels: &[usize],
    reps: usize,
) -> Vec<(usize, f64, f64)> {
    bed.faas.set_batching(batching);
    levels
        .iter()
        .map(|&n| {
            let mut best_wall = f64::INFINITY;
            for _ in 0..reps.max(1) {
                let (wall, _) = run_batch(bed, n);
                best_wall = best_wall.min(wall);
            }
            (n, best_wall, n as f64 / best_wall)
        })
        .collect()
}

/// One mixed-QoS sample: submit `backlog` Batch-class runs, then time a
/// Realtime run from submission to completion; drain the backlog before
/// returning so samples are independent.
fn realtime_latency(bed: &TestBed, backlog: usize) -> f64 {
    let batch: Vec<RunId> = (0..backlog)
        .map(|_| {
            bed.faas
                .submit_workflow_qos("chain", &HashMap::new(), QoS::class(Priority::Batch))
                .unwrap()
        })
        .collect();
    let t0 = std::time::Instant::now();
    let rt = bed
        .faas
        .submit_workflow_qos("chain", &HashMap::new(), QoS::class(Priority::Realtime))
        .unwrap();
    bed.faas.wait_workflow(rt, 120.0).unwrap();
    let latency = t0.elapsed().as_secs_f64();
    for id in batch {
        bed.faas.wait_workflow(id, 120.0).unwrap();
    }
    latency
}

/// Section 6: a handle whose `usage()` is a real loopback-HTTP Prometheus
/// scrape — the per-resource monitoring round trip the snapshot plane
/// amortizes. Scheduling never touches the other verbs.
struct ScrapeHandle {
    addr: String,
}

impl ResourceHandle for ScrapeHandle {
    fn deploy(
        &self,
        _name: &str,
        _image: &str,
        _memory: u64,
        _gpus: u32,
        _labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        Ok(())
    }
    fn remove(&self, _name: &str) -> anyhow::Result<()> {
        Ok(())
    }
    fn invoke(&self, _name: &str, _payload: &Bytes) -> anyhow::Result<(Bytes, f64)> {
        anyhow::bail!("control-plane bench never invokes")
    }
    fn list(&self) -> anyhow::Result<Vec<String>> {
        Ok(vec![])
    }
    fn describe(&self, _name: &str) -> anyhow::Result<Json> {
        anyhow::bail!("unused")
    }
    fn usage(&self) -> anyhow::Result<ResourceUsage> {
        edgefaas::monitor::scrape::scrape(&self.addr)
    }
    fn make_bucket(&self, _bucket: &str) -> anyhow::Result<()> {
        Ok(())
    }
    fn remove_bucket(&self, _bucket: &str) -> anyhow::Result<()> {
        Ok(())
    }
    fn put_object(&self, _bucket: &str, _object: &str, _data: Bytes) -> anyhow::Result<()> {
        Ok(())
    }
    fn get_object(&self, _bucket: &str, _object: &str) -> anyhow::Result<Bytes> {
        anyhow::bail!("unused")
    }
    fn remove_object(&self, _bucket: &str, _object: &str) -> anyhow::Result<()> {
        Ok(())
    }
    fn list_objects(&self, _bucket: &str) -> anyhow::Result<Vec<String>> {
        Ok(vec![])
    }
    fn stored_bytes(&self) -> anyhow::Result<u64> {
        Ok(0)
    }
}

/// Section 6: a coordinator with `n` IoT resources on a star topology
/// (edge hub, distinct leaf latencies) whose monitoring endpoint is a real
/// scrape of `addr`, plus a data-affinity request anchored at the first
/// resource — phase 1 consults all `n` resources per decision.
fn schedule_bed(n: usize, addr: &str) -> (Arc<EdgeFaaS>, FunctionCreation) {
    let mut topo = Topology::new();
    let hub = topo.add_node("hub", Tier::Edge);
    let mut leaves = Vec::new();
    for i in 0..n {
        let leaf = topo.add_node(format!("iot-{i}"), Tier::Iot);
        topo.add_link(leaf, hub, 0.001 + i as f64 * 1e-4, mbps(100.0));
        leaves.push(leaf);
    }
    let faas = Arc::new(EdgeFaaS::with_parts(
        topo,
        DurableKv::ephemeral(),
        Arc::new(RealClock::new()),
    ));
    let mut first = 0;
    for (i, leaf) in leaves.into_iter().enumerate() {
        let spec = ResourceSpec::paper_iot(&format!("pi{i}:8080"));
        let handle = Arc::new(ScrapeHandle { addr: addr.to_string() });
        let id = faas.register(spec, handle, leaf).unwrap();
        if i == 0 {
            first = id;
        }
    }
    let request = FunctionCreation {
        app: "ctl".into(),
        function: FunctionConfig {
            name: "probe".into(),
            dependencies: vec![],
            requirements: Requirements::default(),
            affinity: Affinity { nodetype: Tier::Iot, affinitytype: AffinityType::Data },
            reduce: Reduce::One,
        },
        data_locations: vec![first],
        dep_locations: vec![],
    };
    (faas, request)
}

/// Section 8: a live in-process resource with a kill switch — `kill()`
/// makes the data-plane verbs and the monitoring scrape fail the way a
/// dead box does (connection refused), without tearing the backend down,
/// so `revive()` brings the same state back.
struct MortalHandle {
    inner: Arc<dyn ResourceHandle>,
    dead: AtomicBool,
}

impl MortalHandle {
    fn kill(&self) {
        self.dead.store(true, Ordering::SeqCst);
    }
    fn revive(&self) {
        self.dead.store(false, Ordering::SeqCst);
    }
    fn check(&self) -> anyhow::Result<()> {
        if self.dead.load(Ordering::SeqCst) {
            anyhow::bail!("connection refused (node down)");
        }
        Ok(())
    }
}

impl ResourceHandle for MortalHandle {
    fn deploy(
        &self,
        name: &str,
        image: &str,
        memory: u64,
        gpus: u32,
        labels: &[(String, String)],
    ) -> anyhow::Result<()> {
        self.check()?;
        self.inner.deploy(name, image, memory, gpus, labels)
    }
    fn remove(&self, name: &str) -> anyhow::Result<()> {
        self.check()?;
        self.inner.remove(name)
    }
    fn invoke(&self, name: &str, payload: &Bytes) -> anyhow::Result<(Bytes, f64)> {
        self.check()?;
        self.inner.invoke(name, payload)
    }
    fn invoke_batch(&self, calls: &[BatchCall]) -> Vec<anyhow::Result<(Bytes, f64)>> {
        if self.dead.load(Ordering::SeqCst) {
            return calls
                .iter()
                .map(|_| Err(anyhow::anyhow!("connection refused (node down)")))
                .collect();
        }
        self.inner.invoke_batch(calls)
    }
    fn list(&self) -> anyhow::Result<Vec<String>> {
        self.check()?;
        self.inner.list()
    }
    fn describe(&self, name: &str) -> anyhow::Result<Json> {
        self.check()?;
        self.inner.describe(name)
    }
    fn usage(&self) -> anyhow::Result<ResourceUsage> {
        self.check()?;
        self.inner.usage()
    }
    fn make_bucket(&self, b: &str) -> anyhow::Result<()> {
        self.inner.make_bucket(b)
    }
    fn remove_bucket(&self, b: &str) -> anyhow::Result<()> {
        self.inner.remove_bucket(b)
    }
    fn put_object(&self, b: &str, o: &str, d: Bytes) -> anyhow::Result<()> {
        self.inner.put_object(b, o, d)
    }
    fn get_object(&self, b: &str, o: &str) -> anyhow::Result<Bytes> {
        self.inner.get_object(b, o)
    }
    fn remove_object(&self, b: &str, o: &str) -> anyhow::Result<()> {
        self.inner.remove_object(b, o)
    }
    fn list_objects(&self, b: &str) -> anyhow::Result<Vec<String>> {
        self.inner.list_objects(b)
    }
    fn stored_bytes(&self) -> anyhow::Result<u64> {
        self.inner.stored_bytes()
    }
}

const LIVE_YAML: &str = "\
application: live
entrypoint: f
dag:
  - name: f
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
";

/// Section 8: `n` one-box IoT resources behind an edge hub, each hosting
/// one data anchor of the `live` fan-out app (so a run puts one instance
/// on every schedulable resource), every handle killable.
fn liveness_bed(n: usize) -> (Arc<EdgeFaaS>, Vec<Arc<MortalHandle>>, Vec<ResourceId>) {
    let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
    let mut topo = Topology::new();
    let hub = topo.add_node("hub", Tier::Edge);
    let nodes: Vec<usize> = (0..n)
        .map(|i| {
            let leaf = topo.add_node(format!("live-{i}"), Tier::Iot);
            topo.add_link(leaf, hub, 0.001, mbps(100.0));
            leaf
        })
        .collect();
    let executor = Arc::new(NativeExecutor::new());
    executor.register("img/live", |_: &[u8]| {
        let mut out = Json::obj();
        out.set("outputs", Json::Arr(vec![]));
        Ok(out.to_string().into_bytes())
    });
    let faas = Arc::new(EdgeFaaS::with_parts(topo, DurableKv::ephemeral(), Arc::clone(&clock)));
    let mut handles = Vec::new();
    let mut resources = Vec::new();
    for (i, node) in nodes.into_iter().enumerate() {
        let spec = ResourceSpec::paper_iot(&format!("live{i}:8080"));
        let backend = Arc::new(FaasBackend::new(
            spec.clone(),
            Arc::clone(&executor) as Arc<dyn Executor>,
            Arc::clone(&clock),
        ));
        let store = Arc::new(ObjectStore::new(
            spec.storage * spec.nodes as u64,
            &spec.minio_access_key,
            &spec.minio_secret_key,
        ));
        let handle = Arc::new(MortalHandle {
            inner: Arc::new(LocalHandle::new(backend, store)) as Arc<dyn ResourceHandle>,
            dead: AtomicBool::new(false),
        });
        let id =
            faas.register(spec, Arc::clone(&handle) as Arc<dyn ResourceHandle>, node).unwrap();
        handles.push(handle);
        resources.push(id);
    }
    let mut data = HashMap::new();
    data.insert("f".to_string(), resources.clone());
    faas.configure_application(LIVE_YAML, &data).unwrap();
    faas.deploy_function("live", "f", &FunctionPackage { code: "img/live".into() }).unwrap();
    (faas, handles, resources)
}

/// One churn round at `n` resources: kill one, sweep until the lease
/// detector marks it Dead (drain + relocation ride inside that sweep),
/// run on the survivors, revive, sweep until re-admitted. Returns
/// (time-to-detect, detecting-sweep wall seconds, MTTR, time-to-readmit) —
/// the times in virtual seconds, the sweep cost in wall seconds.
fn churn_round(n: usize, sweep_s: f64) -> (f64, f64, f64, f64) {
    let (faas, handles, resources) = liveness_bed(n);
    faas.refresh_monitor_snapshot();
    let warm = faas.submit_workflow("live", &HashMap::new()).unwrap();
    faas.wait_workflow(warm, 120.0).unwrap();

    let victim = resources[0];
    let lease = |id: ResourceId| faas.monitor_snapshot().lease_of(id).expect("lease").state;
    handles[0].kill();
    let t_kill = faas.clock().now();
    let mut drain_wall = 0.0;
    for sweep in 0.. {
        assert!(sweep < 64, "victim never marked Dead after {sweep} sweeps");
        faas.clock().sleep(sweep_s);
        let t = std::time::Instant::now();
        faas.refresh_monitor_snapshot();
        drain_wall = t.elapsed().as_secs_f64();
        if lease(victim) == LeaseState::Dead {
            break;
        }
    }
    let detect = faas.clock().now() - t_kill;
    let survivors = faas.candidates_of("live", "f").unwrap();
    assert_eq!(survivors.len(), n - 1, "dead resource must leave the candidate set");
    assert!(!survivors.contains(&victim));

    let post = faas.submit_workflow("live", &HashMap::new()).unwrap();
    faas.wait_workflow(post, 120.0).expect("survivors must carry the run");
    let mttr = faas.clock().now() - t_kill;

    handles[0].revive();
    let t_revive = faas.clock().now();
    for sweep in 0.. {
        assert!(sweep < 64, "victim never re-admitted after {sweep} sweeps");
        faas.clock().sleep(sweep_s);
        faas.refresh_monitor_snapshot();
        if lease(victim) == LeaseState::Alive {
            break;
        }
    }
    let readmit = faas.clock().now() - t_revive;
    assert_eq!(
        faas.candidates_of("live", "f").unwrap().len(),
        n,
        "re-admitted resource must rejoin the candidate set"
    );
    (detect, drain_wall, mttr, readmit)
}

/// Section 9: `n` resources as real HTTP pairs — a [`FaasGateway`] and a
/// [`MetricsGateway`] exporter behind an [`HttpHandle`] with budgeted
/// verbs — hosting one anchor of the `live` fan-out app each, so the
/// seeded fault injector can corrupt the wire itself. Returns the
/// coordinator, resource ids, gateway + exporter addresses, and the
/// servers (kept alive by the caller).
fn faults_wire_bed(
    n: usize,
    retry: bool,
) -> (Arc<EdgeFaaS>, Vec<ResourceId>, Vec<String>, Vec<String>, Vec<HttpServer>) {
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
    let mut topo = Topology::new();
    let hub = topo.add_node("hub", Tier::Edge);
    let nodes: Vec<usize> = (0..n)
        .map(|i| {
            let leaf = topo.add_node(format!("wire-{i}"), Tier::Iot);
            topo.add_link(leaf, hub, 0.001, mbps(100.0));
            leaf
        })
        .collect();
    let executor = Arc::new(NativeExecutor::new());
    executor.register("img/live", |_: &[u8]| {
        let mut out = Json::obj();
        out.set("outputs", Json::Arr(vec![]));
        Ok(out.to_string().into_bytes())
    });
    let faas = Arc::new(EdgeFaaS::with_parts(topo, DurableKv::ephemeral(), Arc::clone(&clock)));
    // Tight budgets: a black-holed peer costs hundreds of milliseconds,
    // not the 60 s production defaults. `retry` is the bench's on/off arm.
    let budgets = VerbBudgets {
        connect: Duration::from_millis(500),
        control: Duration::from_secs(5),
        usage: Duration::from_millis(300),
        object: Duration::from_secs(5),
        invoke: Duration::from_millis(800),
        federation: Duration::from_millis(800),
        retries: 2,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(5),
        retry,
    };
    let mut resources = Vec::new();
    let (mut faas_addrs, mut metrics_addrs) = (Vec::new(), Vec::new());
    let mut servers = Vec::new();
    for (i, node) in nodes.into_iter().enumerate() {
        let spec = ResourceSpec::paper_iot(&format!("wire{i}:8080"));
        let backend = Arc::new(FaasBackend::new(
            spec.clone(),
            Arc::clone(&executor) as Arc<dyn Executor>,
            Arc::clone(&clock),
        ));
        let gateway = Arc::new(FaasGateway::new(backend)) as Arc<dyn HttpHandler>;
        let gw = HttpServer::bind(0, 4, gateway).expect("bind faas gateway");
        let registry = Arc::new(MetricsRegistry::new());
        registry.record_usage(&ResourceUsage {
            mem_total: spec.total_memory(),
            gpus_total: spec.total_gpus(),
            ..ResourceUsage::default()
        });
        let metrics = MetricsGateway::serve(registry).expect("bind metrics exporter");
        let handle = HttpHandle::new(gw.addr(), spec.pwd.as_str(), "", "", "", metrics.addr())
            .with_budgets(budgets.clone());
        let id = faas.register(spec, Arc::new(handle) as Arc<dyn ResourceHandle>, node).unwrap();
        resources.push(id);
        faas_addrs.push(gw.addr());
        metrics_addrs.push(metrics.addr());
        servers.extend([gw, metrics]);
    }
    let mut data = HashMap::new();
    data.insert("f".to_string(), resources.clone());
    faas.configure_application(LIVE_YAML, &data).unwrap();
    faas.deploy_function("live", "f", &FunctionPackage { code: "img/live".into() }).unwrap();
    (faas, resources, faas_addrs, metrics_addrs, servers)
}

/// One goodput cell: `runs` sequential 16-instance runs under `rate`
/// injected resets on every gateway link, retries per the bed's budgets.
/// A monitor sweep between runs plays the periodic sweeper, healing
/// data-path Suspect leases so the cell measures goodput, not churn.
/// Returns (completed, failed, completed-run wall latencies).
fn fault_cell(rate: f64, retry: bool, runs: usize, seed: u64) -> (usize, usize, Vec<f64>) {
    let (faas, _resources, faas_addrs, _metrics_addrs, _servers) = faults_wire_bed(16, retry);
    faas.refresh_monitor_snapshot();
    faults::injector().install(seed);
    if rate > 0.0 {
        for (i, addr) in faas_addrs.iter().enumerate() {
            faults::injector().add_rule(
                FaultRule::new(addr, FaultKind::ErrorRate { rate }).tagged(format!("flaky-{i}")),
            );
        }
    }
    let (mut completed, mut failed) = (0usize, 0usize);
    let mut latencies = Vec::new();
    for _ in 0..runs {
        let t = std::time::Instant::now();
        match faas.submit_workflow("live", &HashMap::new()) {
            Err(_) => failed += 1,
            Ok(run) => match faas.wait_workflow(run, 120.0) {
                Ok(_) => {
                    completed += 1;
                    latencies.push(t.elapsed().as_secs_f64());
                }
                Err(_) => failed += 1,
            },
        }
        faas.refresh_monitor_snapshot();
    }
    faults::injector().clear();
    (completed, failed, latencies)
}

/// Section 9, detection arm: a 4-resource wire bed with one resource
/// fully black-holed (invokes *and* scrapes). Returns wall seconds from
/// the fault to the victim's lease first reading Suspect — once driven by
/// live traffic (the data-path miss reporter), once left to a periodic
/// sweeper alone.
fn time_to_suspect(sweep_interval_s: f64) -> (f64, f64) {
    let suspect = |faas: &Arc<EdgeFaaS>, victim: ResourceId| {
        faas.monitor_snapshot()
            .lease_of(victim)
            .map(|l| l.state == LeaseState::Suspect)
            .unwrap_or(false)
    };
    let partition = |faas_addr: &str, metrics_addr: &str| {
        let inj = faults::injector();
        inj.install(0xDA7A);
        inj.add_rule(FaultRule::new(faas_addr, FaultKind::BlackHole).tagged("victim-faas"));
        inj.add_rule(FaultRule::new(metrics_addr, FaultKind::BlackHole).tagged("victim-metrics"));
    };

    // Data-path arm: submit one run; its faulted instance reports the miss
    // long before any sweep fires.
    let (faas, resources, faas_addrs, metrics_addrs, _servers) = faults_wire_bed(4, true);
    faas.refresh_monitor_snapshot();
    let victim = resources[1];
    partition(&faas_addrs[1], &metrics_addrs[1]);
    let t0 = std::time::Instant::now();
    let run = faas.submit_workflow("live", &HashMap::new()).unwrap();
    while !suspect(&faas, victim) {
        assert!(t0.elapsed().as_secs_f64() < 30.0, "data-path evidence never arrived");
        std::thread::sleep(Duration::from_millis(1));
    }
    let data_path_s = t0.elapsed().as_secs_f64();
    faas.wait_workflow(run, 120.0).expect("the faulted instance must relocate");
    faults::injector().clear();

    // Sweep-only arm: identical partition, no traffic — detection waits
    // for the sweeper's next tick.
    let (faas, resources, faas_addrs, metrics_addrs, _servers) = faults_wire_bed(4, true);
    faas.refresh_monitor_snapshot();
    let victim = resources[1];
    partition(&faas_addrs[1], &metrics_addrs[1]);
    let t0 = std::time::Instant::now();
    while !suspect(&faas, victim) {
        assert!(t0.elapsed().as_secs_f64() < 30.0, "sweeps never saw the partition");
        std::thread::sleep(Duration::from_secs_f64(sweep_interval_s));
        faas.refresh_monitor_snapshot();
    }
    let sweep_only_s = t0.elapsed().as_secs_f64();
    faults::injector().clear();
    (data_path_s, sweep_only_s)
}

/// p99 over raw samples (Stats carries p50/p95; the fault plane's tail
/// target is p99).
fn p99_of(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[((v.len() - 1) as f64 * 0.99).round() as usize]
}

/// Section 10: per-instance modeled compute on the federated wire bed.
const FED_STAGE_S: f64 = 0.005;

/// Section 10: `n` coordinators federated over one shared `cells x boxes`
/// fleet. Every coordinator serves a real REST gateway and reaches its
/// peers only through those sockets (gossip, forwarding, stealing);
/// `napps` single-stage fan-out apps (`fedbench{i}`, anchored on cell
/// `i % cells`'s boxes) are configured and deployed only on their
/// hash-owner. Handlers sleep [`FED_STAGE_S`] and count executions on the
/// *shared* backends, so a duplicate or lost execution is observable no
/// matter which coordinator dispatched it. Returns (bed, gateway addrs,
/// federations, app names, app owner indices, per-app execution counters,
/// servers — kept alive by the caller).
#[allow(clippy::type_complexity)]
fn federation_wire_bed(
    n: usize,
    cells: usize,
    boxes: usize,
    napps: usize,
    steal_threshold: usize,
) -> (
    FederatedBed,
    Vec<String>,
    Vec<Arc<Federation>>,
    Vec<String>,
    Vec<usize>,
    Vec<Arc<AtomicUsize>>,
    Vec<HttpServer>,
) {
    let bed = federated_testbed(Arc::new(RealClock::new()), n, cells, boxes);
    let servers: Vec<HttpServer> = bed
        .coordinators
        .iter()
        .map(|c| EdgeFaasGateway::serve(Arc::clone(c), 32).expect("bind coordinator gateway"))
        .collect();
    let addrs: Vec<String> = servers.iter().map(|s| s.addr()).collect();
    let feds: Vec<Arc<Federation>> = (0..n)
        .map(|k| {
            let mut cfg = FederationConfig::new(k as u32, n as u32);
            cfg.steal_threshold = steal_threshold;
            for (j, addr) in addrs.iter().enumerate() {
                if j != k {
                    cfg = cfg.peer(j as u32, addr.clone());
                }
            }
            Federation::enable(&bed.coordinators[k], cfg).expect("enable federation")
        })
        .collect();
    let (mut apps, mut owners, mut counts) = (Vec::new(), Vec::new(), Vec::new());
    for i in 0..napps {
        let app = format!("fedbench{i}");
        let count = Arc::new(AtomicUsize::new(0));
        {
            let count = Arc::clone(&count);
            let clock = Arc::clone(bed.coordinators[0].clock());
            bed.executor.register(&format!("img/{app}"), move |_: &[u8]| {
                clock.sleep(FED_STAGE_S);
                count.fetch_add(1, Ordering::SeqCst);
                Ok(br#"{"outputs":[]}"#.to_vec())
            });
        }
        let owner = feds[0].owner_of_app(&app) as usize;
        let yaml = format!(
            "application: {app}\nentrypoint: f\ndag:\n  - name: f\n    affinity:\n      \
             nodetype: iot\n      affinitytype: data\n    reduce: auto\n"
        );
        let mut data = HashMap::new();
        data.insert("f".to_string(), bed.cell_boxes[i % cells].clone());
        bed.coordinators[owner].configure_application(&yaml, &data).unwrap();
        bed.coordinators[owner]
            .deploy_function(&app, "f", &FunctionPackage { code: format!("img/{app}") })
            .unwrap();
        apps.push(app);
        owners.push(owner);
        counts.push(count);
    }
    // Seed every snapshot: each member sweeps its owned slice, then
    // gossips it to the peers over the wire — after this, every
    // coordinator can schedule onto the whole fleet.
    for fed in &feds {
        fed.sweep_owned();
    }
    for fed in &feds {
        fed.push_gossip();
    }
    (bed, addrs, feds, apps, owners, counts, servers)
}

/// One sustained-submission series: `clients` threads each POST `reqs`
/// synchronous Realtime runs, cycling over the apps and routing every
/// submission to its owner's gateway. Returns (wall seconds,
/// submissions/sec, per-run latency stats, p99).
fn federation_series(
    addrs: &[String],
    apps: &[String],
    owners: &[usize],
    clients: usize,
    reqs: usize,
) -> (f64, f64, Stats, f64) {
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let addrs = addrs.to_vec();
            let apps = apps.to_vec();
            let owners = owners.to_vec();
            std::thread::spawn(move || {
                let mut lat = Vec::with_capacity(reqs);
                for j in 0..reqs {
                    let i = (c + j) % apps.len();
                    let path = format!("/apps/{}/run?priority=realtime", apps[i]);
                    let t = std::time::Instant::now();
                    let resp = http::post_json(&addrs[owners[i]], &path, &Json::obj()).unwrap();
                    assert_eq!(resp.status, 200, "{}", resp.body_str().unwrap_or(""));
                    lat.push(t.elapsed().as_secs_f64());
                }
                lat
            })
        })
        .collect();
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    let p99 = p99_of(&all);
    (wall, (clients * reqs) as f64 / wall, Stats::of(all), p99)
}

/// One federation round at `n` coordinators on a fresh shared fleet: an
/// untimed warm pass (pays every box's cold start and spins up its one
/// replica per function), then the timed Realtime series, with background
/// federation drivers gossiping and polling for steals throughout.
/// Returns (submissions/sec, latency stats, p99, max gossip staleness
/// across members — `None` with a single coordinator — executions
/// observed, executions expected).
fn federation_round(
    n: usize,
    cells: usize,
    boxes: usize,
    napps: usize,
    clients: usize,
    reqs: usize,
) -> (f64, Stats, f64, Option<f64>, usize, usize) {
    let (bed, addrs, feds, apps, owners, counts, _servers) =
        federation_wire_bed(n, cells, boxes, napps, 8);
    for c in &bed.coordinators {
        // A fixed worker budget per coordinator (the scaling lever under
        // test) and one admission slot per box: each box keeps exactly
        // one warm replica per function, so the 1.8 s IoT cold start is
        // paid once per (function, box), in the warm pass, never in the
        // timed series.
        c.set_engine_limits(8, 1);
    }
    for fed in &feds {
        fed.start(0.2);
    }
    // Warm pass at full client concurrency; every app is hit because the
    // clients' app cycles start at distinct offsets.
    let _ = federation_series(&addrs, &apps, &owners, clients, 1);
    let (_, rate, lat, p99) = federation_series(&addrs, &apps, &owners, clients, reqs);
    let stale = feds
        .iter()
        .filter_map(|f| f.gossip_staleness())
        .fold(None, |a: Option<f64>, s| Some(a.map_or(s, |a| a.max(s))));
    for fed in &feds {
        fed.stop();
    }
    // Synchronous runs: every execution landed before its POST returned,
    // so the counters must equal (warm + timed) submissions x fan-out.
    let executed: usize = counts.iter().map(|c| c.load(Ordering::SeqCst)).sum();
    let expected = clients * (1 + reqs) * boxes;
    (rate, lat, p99, stale, executed, expected)
}

/// Section 7: `clients` threads each issue `reqs` echo requests against
/// `server`, fresh-connection (`request_fresh`) or pooled keep-alive
/// (`request`). Returns (wall seconds, requests/sec, per-request latency
/// stats across all clients).
fn net_series(server: &HttpServer, fresh: bool, clients: usize, reqs: usize) -> (f64, f64, Stats) {
    let addr = server.addr();
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut lat = Vec::with_capacity(reqs);
                for _ in 0..reqs {
                    let t = std::time::Instant::now();
                    let resp = if fresh {
                        http::request_fresh(&addr, "POST", "/echo", &[], b"x").unwrap()
                    } else {
                        http::request(&addr, "POST", "/echo", &[], b"x").unwrap()
                    };
                    assert_eq!(resp.status, 200);
                    lat.push(t.elapsed().as_secs_f64());
                }
                lat
            })
        })
        .collect();
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    (wall, (clients * reqs) as f64 / wall, Stats::of(all))
}

fn stats_json(s: &Stats) -> Json {
    let mut o = Json::obj();
    o.set("p50", s.p50.into()).set("p95", s.p95.into()).set("mean", s.mean.into());
    o
}

fn series_json(rows: &[(usize, f64, f64)]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|&(n, wall, rate)| {
                let mut o = Json::obj();
                o.set("concurrency", (n as u64).into())
                    .set("batch_wall_s", wall.into())
                    .set("runs_per_s", rate.into());
                o
            })
            .collect(),
    )
}

fn main() {
    let smoke = std::env::var("ABLATION_SMOKE").map(|v| v == "1").unwrap_or(false);
    let levels: Vec<usize> = if smoke { vec![1, 4] } else { vec![1, 4, 16, 64] };
    let reps = if smoke { 1 } else { 5 };

    if !smoke {
        // ---- Section 1: wall clock with real 5 ms stages. ----
        let mut t = Table::new(
            "Ablation: concurrent workflow runs through the engine (wall clock)",
            &["concurrency", "batch wall", "runs/s", "speedup vs serial"],
        );
        let bed = bed_with_sleeping_chain(Arc::new(RealClock::new()));
        let (serial_wall, _) = run_batch(&bed, 1); // warm sandboxes
        let mut serial_rate = 1.0 / serial_wall;
        let mut rows = Vec::new();
        for &n in &levels {
            let (wall, _) = run_batch(&bed, n);
            let rate = n as f64 / wall;
            if n == 1 {
                serial_rate = rate;
            }
            rows.push((n, wall, rate));
        }
        for (n, wall, rate) in &rows {
            t.row(&[
                n.to_string(),
                Stats::fmt(*wall),
                format!("{rate:.0}"),
                format!("{:.1}x", rate / serial_rate),
            ]);
        }
        t.print();
        let peak = rows.iter().map(|(_, _, r)| *r).fold(0.0, f64::max);
        assert!(
            peak > serial_rate * 1.5,
            "concurrent submission must beat serial throughput: serial {serial_rate:.0}/s peak {peak:.0}/s"
        );

        // ---- Section 2: the same engine under simnet virtual time. ----
        let mut tv = Table::new(
            "Same engine under simnet virtual time",
            &["concurrency", "batch wall", "mean virtual duration"],
        );
        let bed = bed_with_sleeping_chain(Arc::new(VirtualClock::new()));
        let _ = run_batch(&bed, 1); // warm sandboxes (virtual cold starts)
        for &n in &levels {
            let (wall, vdur) = run_batch(&bed, n);
            tv.row(&[n.to_string(), Stats::fmt(wall), format!("{vdur:.3} s")]);
        }
        tv.print();
        println!("\n-> no real sleeping under the virtual clock: the batch's wall time");
        println!("   is pure engine overhead. Per-run virtual durations share one");
        println!("   monotonic clock, so they accumulate with concurrency (per-run");
        println!("   virtual timelines are a ROADMAP open item).");
    }

    // ---- Section 3: hot-path dispatch overhead, batched vs unbatched. ----
    let bed = bed_with_hotpath_chain();
    let _ = run_batch(&bed, 1); // warm sandboxes once

    // Per-run dispatch overhead (batching at the shipped default).
    bed.faas.set_batching(true);
    let overhead = measure(if smoke { 2 } else { 20 }, if smoke { 10 } else { 200 }, || {
        let _ = run_batch(&bed, 1);
    });

    let unbatched = hotpath_series(&bed, false, &levels, reps);
    let batched = hotpath_series(&bed, true, &levels, reps);
    bed.faas.set_batching(true); // leave the default behind

    let mut th = Table::new(
        "Hot path: dispatch overhead, per-resource batching off vs on (virtual clock, zero-work stages)",
        &["concurrency", "unbatched runs/s", "batched runs/s", "batched speedup"],
    );
    for (u, b) in unbatched.iter().zip(&batched) {
        th.row(&[
            u.0.to_string(),
            format!("{:.0}", u.2),
            format!("{:.0}", b.2),
            format!("{:.2}x", b.2 / u.2),
        ]);
    }
    th.print();
    println!(
        "\nper-run dispatch overhead (batched, 1 run = 3 instances): p50 {} p95 {}",
        Stats::fmt(overhead.p50),
        Stats::fmt(overhead.p95)
    );

    // Machine-readable trajectory for future PRs.
    let (max_u, max_b) = (unbatched.last().unwrap(), batched.last().unwrap());
    let speedup = max_b.2 / max_u.2;
    let mut doc = Json::obj();
    let mut series = Json::obj();
    series
        .set("unbatched", series_json(&unbatched))
        .set("batched", series_json(&batched));
    let mut oh = Json::obj();
    oh.set("p50", overhead.p50.into()).set("p95", overhead.p95.into());
    doc.set("bench", "hotpath".into())
        .set("clock", "virtual".into())
        .set("smoke", smoke.into())
        .set("levels", Json::Arr(levels.iter().map(|&n| Json::Num(n as f64)).collect()))
        .set("dispatch_overhead_s", oh)
        .set("series", series)
        .set("speedup_batched_vs_unbatched_at_max_concurrency", speedup.into());
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    std::fs::write(&out_path, doc.to_string()).expect("write bench json");
    println!("wrote {out_path} (speedup at {} concurrent runs: {speedup:.2}x)", max_u.0);

    // ---- Section 4: mixed QoS — Realtime latency under Batch load. ----
    let bed = bed_with_hotpath_chain();
    let _ = run_batch(&bed, 1); // warm sandboxes
    let backlog = 64usize;
    let reps_qos = if smoke { 5 } else { 30 };
    let unloaded = Stats::of((0..reps_qos).map(|_| realtime_latency(&bed, 0)).collect());
    let loaded = Stats::of((0..reps_qos).map(|_| realtime_latency(&bed, backlog)).collect());
    let ratio = loaded.p95 / unloaded.p95;

    let mut tq = Table::new(
        "Mixed QoS: Realtime run latency, unloaded vs 64 Batch runs in flight",
        &["series", "p50", "p95", "mean"],
    );
    tq.row(&[
        "realtime unloaded".into(),
        Stats::fmt(unloaded.p50),
        Stats::fmt(unloaded.p95),
        Stats::fmt(unloaded.mean),
    ]);
    tq.row(&[
        format!("realtime + {backlog} batch"),
        Stats::fmt(loaded.p50),
        Stats::fmt(loaded.p95),
        Stats::fmt(loaded.mean),
    ]);
    tq.print();
    println!("\n-> p95 ratio loaded/unloaded: {ratio:.2}x (priority isolation target: <= 2x)");

    let mut qdoc = Json::obj();
    qdoc.set("bench", "qos".into())
        .set("clock", "virtual".into())
        .set("smoke", smoke.into())
        .set("batch_backlog", (backlog as u64).into())
        .set("reps", (reps_qos as u64).into())
        .set("realtime_unloaded_s", stats_json(&unloaded))
        .set("realtime_with_batch_backlog_s", stats_json(&loaded))
        .set("p95_ratio_loaded_vs_unloaded", ratio.into());
    let qos_path =
        std::env::var("BENCH_QOS_OUT").unwrap_or_else(|_| "BENCH_qos.json".to_string());
    std::fs::write(&qos_path, qdoc.to_string()).expect("write qos bench json");
    println!("wrote {qos_path}");

    // ---- Section 5: lock contention — engine shard sweep. ----
    let shard_counts = [1usize, 4, 16];
    let levels_c: Vec<usize> = if smoke { vec![8] } else { vec![64, 256] };
    let reps_c = if smoke { 1 } else { 3 };
    let mut tc = Table::new(
        "Contention: engine shard sweep on the zero-work hot path (virtual clock)",
        &["shards", "concurrency", "runs/s", "dispatch p50", "dispatch p95"],
    );
    let mut shard_rows: Vec<(usize, Vec<(usize, f64)>, Stats)> = Vec::new();
    for &s in &shard_counts {
        let bed = bed_with_hotpath_chain_sharded(s);
        let _ = run_batch(&bed, 1); // warm sandboxes
        let overhead = measure(if smoke { 2 } else { 10 }, if smoke { 10 } else { 100 }, || {
            let _ = run_batch(&bed, 1);
        });
        let mut rows = Vec::new();
        for &n in &levels_c {
            let mut best_wall = f64::INFINITY;
            for _ in 0..reps_c.max(1) {
                let (wall, _) = run_batch(&bed, n);
                best_wall = best_wall.min(wall);
            }
            rows.push((n, n as f64 / best_wall));
        }
        for (n, rate) in &rows {
            tc.row(&[
                s.to_string(),
                n.to_string(),
                format!("{rate:.0}"),
                Stats::fmt(overhead.p50),
                Stats::fmt(overhead.p95),
            ]);
        }
        shard_rows.push((s, rows, overhead));
    }
    tc.print();
    let rate_at = |shards: usize, n: usize| -> f64 {
        shard_rows
            .iter()
            .find(|(s, _, _)| *s == shards)
            .and_then(|(_, rows, _)| rows.iter().find(|(c, _)| *c == n).map(|(_, r)| *r))
            .unwrap_or(f64::NAN)
    };
    let contention_level = *levels_c.first().unwrap();
    let shard_speedup = rate_at(16, contention_level) / rate_at(1, contention_level);
    println!(
        "\n-> shards=16 vs shards=1 (single-lock layout) at {contention_level} concurrent \
         runs: {shard_speedup:.2}x"
    );
    let mut cdoc = Json::obj();
    let mut sweep = Vec::new();
    for (s, rows, overhead) in &shard_rows {
        let mut o = Json::obj();
        let mut oh = Json::obj();
        oh.set("p50", overhead.p50.into()).set("p95", overhead.p95.into());
        o.set("shards", (*s as u64).into())
            .set(
                "series",
                Json::Arr(
                    rows.iter()
                        .map(|&(n, rate)| {
                            let mut r = Json::obj();
                            r.set("concurrency", (n as u64).into())
                                .set("runs_per_s", rate.into());
                            r
                        })
                        .collect(),
                ),
            )
            .set("dispatch_overhead_s", oh);
        sweep.push(o);
    }
    cdoc.set("bench", "contention".into())
        .set("clock", "virtual".into())
        .set("smoke", smoke.into())
        .set("levels", Json::Arr(levels_c.iter().map(|&n| Json::Num(n as f64)).collect()))
        .set(
            "shard_counts",
            Json::Arr(shard_counts.iter().map(|&n| Json::Num(n as f64)).collect()),
        )
        .set("sweep", Json::Arr(sweep))
        .set("speedup_level", (contention_level as u64).into())
        .set("speedup_sharded_vs_single_lock", shard_speedup.into());
    let contention_path = std::env::var("BENCH_CONTENTION_OUT")
        .unwrap_or_else(|_| "BENCH_contention.json".to_string());
    std::fs::write(&contention_path, cdoc.to_string()).expect("write contention bench json");
    println!("wrote {contention_path}");

    // ---- Section 6: control plane — schedule rate on the snapshot plane. ----
    let levels_s: Vec<usize> = if smoke { vec![8] } else { vec![16, 64, 256] };
    let registry = Arc::new(MetricsRegistry::new());
    registry.record_usage(&ResourceUsage {
        cpu_frac: 0.1,
        mem_used: 1 << 30,
        mem_total: 8 << 30,
        io_bytes_per_s: 0.0,
        gpu_frac: 0.0,
        gpus_used: 0,
        gpus_total: 0,
    });
    let metrics_server = MetricsGateway::serve(Arc::clone(&registry)).expect("metrics gateway");
    let metrics_addr = metrics_server.addr();
    let mut ts = Table::new(
        "Control plane: schedule_function — per-call scrape vs snapshot plane vs decision cache",
        &["resources", "scrape calls/s", "snapshot calls/s", "cached calls/s", "snapshot speedup"],
    );
    // (resources, scrape stats, snapshot stats, cached stats, speedup)
    let mut sched_rows: Vec<(usize, Stats, Stats, Stats, f64)> = Vec::new();
    for &n in &levels_s {
        let (faas, request) = schedule_bed(n, &metrics_addr);
        // Baseline: empty snapshot, cache off — every decision scrapes all
        // n resources over loopback HTTP (the pre-snapshot behaviour).
        faas.set_schedule_cache(false);
        let scrape = measure(1, if smoke { 5 } else { 20 }, || {
            faas.schedule_function(&request).unwrap();
        });
        // Snapshot plane: one refresh, then decisions are in-memory reads
        // (a generous max_age keeps the samples fresh for the whole run).
        faas.set_snapshot_max_age(1e9);
        faas.refresh_monitor_snapshot();
        let reps_mem = if smoke { 50 } else { 500 };
        let snapshot = measure(5, reps_mem, || {
            faas.schedule_function(&request).unwrap();
        });
        // Decision cache on top: repeats of an identical request are hits.
        faas.set_schedule_cache(true);
        let cached = measure(5, reps_mem, || {
            faas.schedule_function(&request).unwrap();
        });
        let speedup = scrape.mean / snapshot.mean;
        ts.row(&[
            n.to_string(),
            format!("{:.0}", 1.0 / scrape.mean),
            format!("{:.0}", 1.0 / snapshot.mean),
            format!("{:.0}", 1.0 / cached.mean),
            format!("{speedup:.1}x"),
        ]);
        sched_rows.push((n, scrape, snapshot, cached, speedup));
    }
    ts.print();
    println!("\n-> the snapshot plane removes O(resources) scrape RTTs from every decision;");
    println!("   the cache removes the remaining phase-1/phase-2 work for repeats.");
    let speedup_level = if smoke { levels_s[0] } else { 64 };
    let schedule_speedup = sched_rows
        .iter()
        .find(|(n, ..)| *n == speedup_level)
        .map(|(_, _, _, _, s)| *s)
        .unwrap_or(f64::NAN);
    let mut sdoc = Json::obj();
    let mut series = Vec::new();
    for (n, scrape, snapshot, cached, speedup) in &sched_rows {
        let mode = |s: &Stats| {
            let mut o = stats_json(s);
            o.set("calls_per_s", (1.0 / s.mean).into());
            o
        };
        let mut o = Json::obj();
        o.set("resources", (*n as u64).into())
            .set("scrape", mode(scrape))
            .set("snapshot", mode(snapshot))
            .set("cached", mode(cached))
            .set("speedup_snapshot_vs_scrape", (*speedup).into());
        series.push(o);
    }
    sdoc.set("bench", "schedule".into())
        .set("clock", "real".into())
        .set("smoke", smoke.into())
        .set("levels", Json::Arr(levels_s.iter().map(|&n| Json::Num(n as f64)).collect()))
        .set("series", Json::Arr(series))
        .set("speedup_level", (speedup_level as u64).into())
        .set("speedup_snapshot_vs_scrape", schedule_speedup.into());
    let schedule_path = std::env::var("BENCH_SCHEDULE_OUT")
        .unwrap_or_else(|_| "BENCH_schedule.json".to_string());
    std::fs::write(&schedule_path, sdoc.to_string()).expect("write schedule bench json");
    println!("wrote {schedule_path} (snapshot speedup at {speedup_level} resources: {schedule_speedup:.1}x)");
    drop(metrics_server);

    // ---- Section 7: network plane — keep-alive + epoll throughput. ----
    http::set_pool_per_addr(64);
    let clients_levels: Vec<usize> = if smoke { vec![1, 4] } else { vec![1, 16, 64] };
    let reqs_per_client = if smoke { 10 } else { 200 };
    let echo: Arc<dyn HttpHandler> =
        Arc::new(|req: HttpRequest| HttpResponse::bytes(200, req.body));
    let fallback_opts = ServerOptions { force_fallback: true, ..ServerOptions::default() };
    // (mode name, fresh connection per request?, server options)
    let net_modes: Vec<(&str, bool, ServerOptions)> = vec![
        ("fresh", true, fallback_opts.clone()),
        ("pooled", false, fallback_opts),
        ("pooled_epoll", false, ServerOptions::default()),
    ];
    let mut tn = Table::new(
        "Network plane: echo throughput — fresh conns vs pooled keep-alive vs epoll server",
        &["mode", "clients", "reqs/s", "p50", "p95"],
    );
    // (mode, clients, wall, reqs/s, latency stats)
    let mut net_rows: Vec<(String, usize, f64, f64, Stats)> = Vec::new();
    for (name, fresh, opts) in net_modes {
        let server = HttpServer::bind_with(0, 8, Arc::clone(&echo), opts).expect("bind echo");
        for &c in &clients_levels {
            let (wall, rate, lat) = net_series(&server, fresh, c, reqs_per_client);
            tn.row(&[
                name.into(),
                c.to_string(),
                format!("{rate:.0}"),
                Stats::fmt(lat.p50),
                Stats::fmt(lat.p95),
            ]);
            net_rows.push((name.to_string(), c, wall, rate, lat));
        }
    }
    tn.print();
    let net_rate = |mode: &str, c: usize| {
        net_rows
            .iter()
            .find(|(m, n, ..)| m == mode && *n == c)
            .map(|(_, _, _, r, _)| *r)
            .unwrap_or(f64::NAN)
    };
    let top_clients = *clients_levels.last().unwrap();
    let net_speedup = net_rate("pooled_epoll", top_clients) / net_rate("fresh", top_clients);
    println!(
        "\n-> pooled keep-alive + platform server vs fresh connections at {top_clients} \
         clients: {net_speedup:.2}x"
    );

    // 1 MiB object PUT/GET through the store gateway: the zero-copy body
    // path (request body -> store by refcount, stored buffer -> response).
    let store = Arc::new(ObjectStore::new(1 << 30, "ak", "sk"));
    let store_server =
        HttpServer::bind(0, 4, Arc::new(StoreGateway::new(store)) as Arc<dyn HttpHandler>)
            .expect("bind store");
    let saddr = store_server.addr();
    store_client::make_bucket(&saddr, "ak", "sk", "bench").unwrap();
    let blob = vec![7u8; 1 << 20];
    let obj_reps = if smoke { 3 } else { 30 };
    let obj_put = Stats::of(
        (0..obj_reps)
            .map(|i| {
                let name = format!("o{i}");
                let t = std::time::Instant::now();
                store_client::put_object(&saddr, "ak", "sk", "bench", &name, &blob).unwrap();
                t.elapsed().as_secs_f64()
            })
            .collect(),
    );
    let obj_get = Stats::of(
        (0..obj_reps)
            .map(|i| {
                let name = format!("o{i}");
                let t = std::time::Instant::now();
                let got = store_client::get_object(&saddr, "ak", "sk", "bench", &name).unwrap();
                assert_eq!(got.len(), blob.len());
                t.elapsed().as_secs_f64()
            })
            .collect(),
    );
    println!(
        "1 MiB object over keep-alive: PUT p50 {} GET p50 {}",
        Stats::fmt(obj_put.p50),
        Stats::fmt(obj_get.p50)
    );

    let mut ndoc = Json::obj();
    let mut mode_arr = Vec::new();
    for mode in ["fresh", "pooled", "pooled_epoll"] {
        let mut o = Json::obj();
        let rows = net_rows.iter().filter(|(m, ..)| m == mode);
        let series = rows
            .map(|(_, c, wall, rate, lat)| {
                let mut r = stats_json(lat);
                r.set("clients", (*c as u64).into())
                    .set("wall_s", (*wall).into())
                    .set("requests_per_s", (*rate).into());
                r
            })
            .collect();
        o.set("mode", mode.into()).set("series", Json::Arr(series));
        mode_arr.push(o);
    }
    let mut obj = Json::obj();
    obj.set("put_s", stats_json(&obj_put)).set("get_s", stats_json(&obj_get));
    ndoc.set("bench", "net".into())
        .set("smoke", smoke.into())
        .set("epoll_available", cfg!(target_os = "linux").into())
        .set("clients", Json::Arr(clients_levels.iter().map(|&n| Json::Num(n as f64)).collect()))
        .set("requests_per_client", (reqs_per_client as u64).into())
        .set("modes", Json::Arr(mode_arr))
        .set("object_1mib", obj)
        .set("speedup_level_clients", (top_clients as u64).into())
        .set("speedup_pooled_epoll_vs_fresh", net_speedup.into());
    let net_path =
        std::env::var("BENCH_NET_OUT").unwrap_or_else(|_| "BENCH_net.json".to_string());
    std::fs::write(&net_path, ndoc.to_string()).expect("write net bench json");
    println!("wrote {net_path} (pooled+epoll speedup at {top_clients} clients: {net_speedup:.2}x)");

    // ---- Section 8: liveness plane — churn detection, drain, recovery. ----
    let sweep_s = 5.0; // virtual seconds between monitor sweeps
    let levels_l: Vec<usize> = if smoke { vec![4] } else { vec![16, 64] };
    let mut tl = Table::new(
        "Liveness: kill one of n resources — detect, drain, recover (virtual clock)",
        &["resources", "time to detect", "detect sweep wall", "MTTR", "time to readmit"],
    );
    // (resources, detect virtual s, detecting-sweep wall s, mttr virtual s, readmit virtual s)
    let mut live_rows: Vec<(usize, f64, f64, f64, f64)> = Vec::new();
    for &n in &levels_l {
        let (detect, drain_wall, mttr, readmit) = churn_round(n, sweep_s);
        tl.row(&[
            n.to_string(),
            format!("{detect:.1} s"),
            Stats::fmt(drain_wall),
            format!("{mttr:.1} s"),
            format!("{readmit:.1} s"),
        ]);
        live_rows.push((n, detect, drain_wall, mttr, readmit));
    }
    tl.print();
    println!("\n-> detect = dead_after sweeps x interval; the detecting sweep's wall time");
    println!("   carries the drain + relocation; MTTR adds the survivors' run itself.");

    // Steady-state lease overhead: the zero-work hot path at the top
    // concurrency level with a monitor sweeper refreshing every 2 ms —
    // far more aggressive than a production sweep cadence — vs without.
    let bed = bed_with_hotpath_chain();
    let _ = run_batch(&bed, 1); // warm sandboxes
    let top = *levels.last().unwrap();
    let reps_l = if smoke { 1 } else { 3 };
    let mut best = f64::INFINITY;
    for _ in 0..reps_l {
        best = best.min(run_batch(&bed, top).0);
    }
    let base_rate = top as f64 / best;
    let stop = Arc::new(AtomicBool::new(false));
    let sweeper = {
        let faas = Arc::clone(&bed.faas);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                faas.refresh_monitor_snapshot();
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        })
    };
    let mut best_swept = f64::INFINITY;
    for _ in 0..reps_l {
        best_swept = best_swept.min(run_batch(&bed, top).0);
    }
    stop.store(true, Ordering::SeqCst);
    sweeper.join().unwrap();
    let swept_rate = top as f64 / best_swept;
    let lease_ratio = swept_rate / base_rate;
    println!(
        "steady-state hot path at {top} concurrent runs: {base_rate:.0} runs/s alone, \
         {swept_rate:.0} runs/s with a 2 ms monitor sweeper ({:.1}% kept)",
        lease_ratio * 100.0
    );

    let live_cfg = edgefaas::monitor::LivenessConfig::default();
    let mut ldoc = Json::obj();
    let mut lseries = Vec::new();
    for &(n, detect, drain_wall, mttr, readmit) in &live_rows {
        let mut o = Json::obj();
        o.set("resources", (n as u64).into())
            .set("time_to_detect_s", detect.into())
            .set("detect_sweep_wall_s", drain_wall.into())
            .set("mttr_s", mttr.into())
            .set("time_to_readmit_s", readmit.into());
        lseries.push(o);
    }
    let mut steady = Json::obj();
    steady
        .set("concurrency", (top as u64).into())
        .set("baseline_runs_per_s", base_rate.into())
        .set("with_sweeper_runs_per_s", swept_rate.into())
        .set("throughput_kept_ratio", lease_ratio.into());
    ldoc.set("bench", "liveness".into())
        .set("clock", "virtual".into())
        .set("smoke", smoke.into())
        .set("sweep_interval_s", sweep_s.into())
        .set("dead_after", (live_cfg.dead_after as u64).into())
        .set("quarantine_sweeps", (live_cfg.quarantine_sweeps as u64).into())
        .set("levels", Json::Arr(levels_l.iter().map(|&n| Json::Num(n as f64)).collect()))
        .set("series", Json::Arr(lseries))
        .set("steady_state", steady);
    let liveness_path = std::env::var("BENCH_LIVENESS_OUT")
        .unwrap_or_else(|_| "BENCH_liveness.json".to_string());
    std::fs::write(&liveness_path, ldoc.to_string()).expect("write liveness bench json");
    println!("wrote {liveness_path} (throughput kept under sweeper: {:.1}%)", lease_ratio * 100.0);

    // --- Section 9: fault plane ------------------------------------------
    // Goodput of 16-instance fan-out runs over real sockets while the
    // seeded injector resets a fraction of gateway requests, with the
    // handle's idempotent-retry budget on vs off; plus time-to-Suspect for
    // a fully partitioned resource, from live traffic vs sweeps alone.
    println!("\nfault plane: goodput under injected wire faults (real clock, real sockets)");
    let fault_rates = [0.0, 0.01, 0.05, 0.10];
    let runs_per_cell = if smoke { 5 } else { 40 };
    let mut fault_rows = Vec::new();
    for (ri, &rate) in fault_rates.iter().enumerate() {
        for &retry in &[true, false] {
            let seed = 0xFA5EED + (ri * 2 + retry as usize) as u64;
            let (completed, failed, lat) = fault_cell(rate, retry, runs_per_cell, seed);
            let goodput = completed as f64 / runs_per_cell as f64;
            let tail = p99_of(&lat);
            fault_rows.push((rate, retry, goodput, completed, failed, Stats::of(lat), tail));
        }
    }
    let mut tf = Table::new(
        "Fault plane: goodput at injected wire-fault rates (16 resources, 16-instance runs)",
        &["fault rate", "retries", "goodput", "completed", "failed", "run p50", "run p99"],
    );
    for &(rate, retry, goodput, completed, failed, ref lat, tail) in &fault_rows {
        tf.row(&[
            format!("{:.0}%", rate * 100.0),
            if retry { "on" } else { "off" }.to_string(),
            format!("{:.1}%", goodput * 100.0),
            completed.to_string(),
            failed.to_string(),
            Stats::fmt(lat.p50),
            Stats::fmt(tail),
        ]);
    }
    tf.print();

    let fault_sweep_s = if smoke { 0.5 } else { 2.0 };
    let (data_path_s, sweep_only_s) = time_to_suspect(fault_sweep_s);
    println!(
        "time-to-Suspect for a fully partitioned resource: {data_path_s:.3}s from live \
         traffic vs {sweep_only_s:.3}s under a {fault_sweep_s:.1}s sweeper alone"
    );

    let mut fdoc = Json::obj();
    let mut fseries = Vec::new();
    for &(rate, retry, goodput, completed, failed, ref lat, tail) in &fault_rows {
        let mut l = Json::obj();
        l.set("p50_s", lat.p50.into())
            .set("p95_s", lat.p95.into())
            .set("mean_s", lat.mean.into())
            .set("p99_s", tail.into());
        let mut o = Json::obj();
        o.set("fault_rate", rate.into())
            .set("retries", retry.into())
            .set("goodput", goodput.into())
            .set("completed", (completed as u64).into())
            .set("failed", (failed as u64).into())
            .set("latency", l);
        fseries.push(o);
    }
    let mut fdetect = Json::obj();
    fdetect
        .set("sweep_interval_s", fault_sweep_s.into())
        .set("data_path_s", data_path_s.into())
        .set("sweep_only_s", sweep_only_s.into());
    fdoc.set("bench", "faults".into())
        .set("clock", "real".into())
        .set("smoke", smoke.into())
        .set("runs_per_cell", (runs_per_cell as u64).into())
        .set("rates", Json::Arr(fault_rates.iter().map(|&r| Json::Num(r)).collect()))
        .set("series", Json::Arr(fseries))
        .set("time_to_suspect", fdetect);
    let faults_path =
        std::env::var("BENCH_FAULTS_OUT").unwrap_or_else(|_| "BENCH_faults.json".to_string());
    std::fs::write(&faults_path, fdoc.to_string()).expect("write faults bench json");
    let goodput_5pct_retries = fault_rows
        .iter()
        .find(|&&(rate, retry, ..)| (rate - 0.05).abs() < 1e-9 && retry)
        .map(|&(_, _, g, ..)| g)
        .unwrap_or(f64::NAN);
    println!(
        "wrote {faults_path} (goodput at 5% faults with retries: {:.1}%)",
        goodput_5pct_retries * 100.0
    );

    // --- Section 10: federation plane -------------------------------------
    // N coordinators jointly serving one shared fleet over real sockets:
    // sustained synchronous Realtime submissions routed to each app's
    // hash-owner while gossip/steal drivers tick, at 1/2/4 coordinators;
    // then a skewed-load round where an idle coordinator must steal a
    // saturated peer's queue over the wire.
    println!("\nfederation plane: sustained submissions vs coordinator count (real sockets)");
    let (fed_cells, fed_boxes) = if smoke { (2, 2) } else { (9, 6) };
    let fed_napps = if smoke { 4 } else { 8 };
    let fed_clients = if smoke { 4 } else { 48 };
    let fed_reqs = if smoke { 4 } else { 32 };
    let member_counts: Vec<usize> = if smoke { vec![1, 2] } else { vec![1, 2, 4] };
    // (coordinators, submissions/s, latency, p99, staleness, executed, expected)
    let mut fed_rows: Vec<(usize, f64, Stats, f64, Option<f64>, usize, usize)> = Vec::new();
    for &n in &member_counts {
        let (rate, lat, p99, stale, executed, expected) =
            federation_round(n, fed_cells, fed_boxes, fed_napps, fed_clients, fed_reqs);
        fed_rows.push((n, rate, lat, p99, stale, executed, expected));
    }
    let fed_base_rate = fed_rows[0].1;
    let mut tfed = Table::new(
        "Federation: sustained Realtime submissions, owner-routed over the shared wire bed",
        &["coordinators", "submissions/s", "p50", "p99", "gossip staleness", "speedup vs 1"],
    );
    for &(n, rate, ref lat, p99, stale, _, _) in &fed_rows {
        tfed.row(&[
            n.to_string(),
            format!("{rate:.0}"),
            Stats::fmt(lat.p50),
            Stats::fmt(p99),
            stale.map(Stats::fmt).unwrap_or_else(|| "-".into()),
            format!("{:.2}x", rate / fed_base_rate),
        ]);
    }
    tfed.print();

    // Skewed load: every submission enters through the idle thief and is
    // forwarded to the single app's owner, whose one-worker engine is
    // pinned by the first cold start — the thief must steal the queued
    // instances over the wire and execute them on the shared backends.
    let (sbed, saddrs, sfeds, sapps, sowners, scounts, _sservers) =
        federation_wire_bed(2, 1, fed_boxes.min(4), 1, 2);
    let victim = sowners[0];
    let thief = 1 - victim;
    sbed.coordinators[victim].set_engine_shards(1);
    sbed.coordinators[victim].set_engine_limits(1, 8);
    sbed.coordinators[thief].set_engine_limits(8, 8);
    let skew_runs = if smoke { 6 } else { 16 };
    let skew_boxes = sbed.cell_boxes[0].len();
    for _ in 0..skew_runs {
        let resp = http::post_json(
            &saddrs[thief],
            &format!("/apps/{}/run?async=true", sapps[0]),
            &Json::obj(),
        )
        .unwrap();
        assert_eq!(resp.status, 202, "{}", resp.body_str().unwrap_or(""));
    }
    let skew_expected = skew_runs * skew_boxes;
    let t0 = std::time::Instant::now();
    while scounts[0].load(Ordering::SeqCst) < skew_expected {
        sfeds[thief].steal_once();
        assert!(
            t0.elapsed().as_secs_f64() < 120.0,
            "skewed fleet failed to drain: {}/{skew_expected} executions",
            scounts[0].load(Ordering::SeqCst)
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    // Loan settlement (the thief's completion reports) trails the last
    // execution — and a duplicate execution would land in this window.
    let t1 = std::time::Instant::now();
    while sbed.coordinators[victim].federation_loans().4 != 0 && t1.elapsed().as_secs_f64() < 30.0
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    std::thread::sleep(Duration::from_millis(100));
    let skew_executed = scounts[0].load(Ordering::SeqCst);
    let (spolls, shits, sstolen, sexecuted, sreturned) = sfeds[thief].steal_counters();
    let (lent, loan_completed, loan_requeued, loan_reclaimed, loan_outstanding) =
        sbed.coordinators[victim].federation_loans();
    let (sforwards, sforward_failures) = sfeds[thief].forward_counters();
    let steal_hit_rate = if spolls > 0 { shits as f64 / spolls as f64 } else { 0.0 };
    println!(
        "skewed load: {skew_runs} forwarded runs, {sstolen} instances stolen over the wire \
         (hit rate {:.0}%), {skew_executed}/{skew_expected} executions, {loan_outstanding} \
         loans outstanding",
        steal_hit_rate * 100.0
    );

    let top_members = *member_counts.last().unwrap();
    let fed_speedup = fed_rows.last().unwrap().1 / fed_base_rate;
    let mut feddoc = Json::obj();
    let mut fed_series = Vec::new();
    for &(n, rate, ref lat, p99, stale, executed, expected) in &fed_rows {
        let mut l = stats_json(lat);
        l.set("p99", p99.into());
        let mut o = Json::obj();
        o.set("coordinators", (n as u64).into())
            .set("submissions_per_s", rate.into())
            .set("latency_s", l)
            .set("executed", (executed as u64).into())
            .set("expected", (expected as u64).into());
        if let Some(s) = stale {
            o.set("gossip_staleness_s", s.into());
        }
        fed_series.push(o);
    }
    let mut loans = Json::obj();
    loans
        .set("lent", lent.into())
        .set("completed", loan_completed.into())
        .set("requeued", loan_requeued.into())
        .set("reclaimed", loan_reclaimed.into())
        .set("outstanding", (loan_outstanding as u64).into());
    let mut steal = Json::obj();
    steal
        .set("polls", spolls.into())
        .set("hits", shits.into())
        .set("hit_rate", steal_hit_rate.into())
        .set("instances_stolen", sstolen.into())
        .set("executed_by_thief", sexecuted.into())
        .set("returned", sreturned.into())
        .set("forwards", sforwards.into())
        .set("forward_failures", sforward_failures.into())
        .set("runs", (skew_runs as u64).into())
        .set("executed", (skew_executed as u64).into())
        .set("expected", (skew_expected as u64).into())
        .set("loans", loans);
    feddoc
        .set("bench", "federation".into())
        .set("clock", "real".into())
        .set("smoke", smoke.into())
        .set("cells", (fed_cells as u64).into())
        .set("boxes_per_cell", (fed_boxes as u64).into())
        .set("apps", (fed_napps as u64).into())
        .set("clients", (fed_clients as u64).into())
        .set("requests_per_client", (fed_reqs as u64).into())
        .set(
            "member_counts",
            Json::Arr(member_counts.iter().map(|&n| Json::Num(n as f64)).collect()),
        )
        .set("series", Json::Arr(fed_series))
        .set("skewed_steal", steal)
        .set("speedup_level_members", (top_members as u64).into())
        .set("speedup_vs_single_coordinator", fed_speedup.into());
    let federation_path = std::env::var("BENCH_FEDERATION_OUT")
        .unwrap_or_else(|_| "BENCH_federation.json".to_string());
    std::fs::write(&federation_path, feddoc.to_string()).expect("write federation bench json");
    println!(
        "wrote {federation_path} (speedup at {top_members} coordinators: {fed_speedup:.2}x)"
    );

    if !smoke {
        assert!(
            fed_speedup >= 1.8,
            "{top_members} coordinators must sustain >= 1.8x the single-coordinator \
             submission rate over the shared fleet: {:.0}/s vs {fed_base_rate:.0}/s \
             ({fed_speedup:.2}x < 1.8x)",
            fed_rows.last().unwrap().1
        );
        assert!(
            sstolen > 0,
            "an idle coordinator facing a saturated peer must steal over the wire"
        );
        for &(n, _, _, _, _, executed, expected) in &fed_rows {
            assert_eq!(
                executed, expected,
                "duplicate or lost executions at {n} coordinator(s)"
            );
        }
        assert_eq!(
            skew_executed, skew_expected,
            "duplicate or lost executions under skewed load"
        );
        assert_eq!(loan_outstanding, 0, "every loan must settle after the skewed drain");
        assert_eq!(sforward_failures, 0, "forwarding through a healthy fleet must not fail");
    }

    if !smoke && cfg!(target_os = "linux") {
        assert!(
            net_speedup >= 2.0,
            "pooled keep-alive + epoll must at least double fresh-connection throughput at \
             {top_clients} concurrent clients: fresh {:.0}/s pooled+epoll {:.0}/s \
             ({net_speedup:.2}x < 2x)",
            net_rate("fresh", top_clients),
            net_rate("pooled_epoll", top_clients),
        );
    }

    if !smoke {
        assert!(
            schedule_speedup >= 5.0,
            "the snapshot plane must beat per-call scraping at {speedup_level} registered \
             resources: scrape {:.0}/s snapshot {:.0}/s ({schedule_speedup:.2}x < 5x)",
            sched_rows
                .iter()
                .find(|(n, ..)| *n == speedup_level)
                .map(|(_, s, ..)| 1.0 / s.mean)
                .unwrap_or(f64::NAN),
            sched_rows
                .iter()
                .find(|(n, ..)| *n == speedup_level)
                .map(|(_, _, s, ..)| 1.0 / s.mean)
                .unwrap_or(f64::NAN),
        );
    }

    if !smoke {
        assert!(
            speedup >= 1.5,
            "batching must amortize dispatch overhead at {} concurrent runs: \
             unbatched {:.0}/s batched {:.0}/s ({speedup:.2}x < 1.5x)",
            max_u.0,
            max_u.2,
            max_b.2
        );
        assert!(
            ratio <= 2.0,
            "the QoS queue must isolate Realtime from a {backlog}-run Batch backlog: \
             p95 {} loaded vs {} unloaded ({ratio:.2}x > 2x)",
            Stats::fmt(loaded.p95),
            Stats::fmt(unloaded.p95)
        );
        assert!(
            shard_speedup >= 1.5,
            "sharding must relieve the dispatch/run-table locks at {contention_level} \
             concurrent runs: shards=1 {:.0}/s shards=16 {:.0}/s ({shard_speedup:.2}x < 1.5x)",
            rate_at(1, contention_level),
            rate_at(16, contention_level),
        );
        assert!(
            lease_ratio >= 0.95,
            "lease bookkeeping must cost <= 5% of hot-path throughput at {top} concurrent \
             runs: {base_rate:.0}/s alone vs {swept_rate:.0}/s under a 2 ms sweeper \
             ({:.1}% kept < 95%)",
            lease_ratio * 100.0
        );
        for &(n, detect, _, _, _) in &live_rows {
            let bound = (live_cfg.dead_after as f64 + 0.5) * sweep_s;
            assert!(
                detect <= bound,
                "detection at {n} resources must complete within dead_after sweeps: \
                 {detect:.1}s > {bound:.1}s"
            );
        }
        assert!(
            goodput_5pct_retries >= 0.9,
            "idempotent retries must hold >=90% goodput at a 5% wire-fault rate: \
             {:.1}% < 90%",
            goodput_5pct_retries * 100.0
        );
        assert!(
            data_path_s < sweep_only_s,
            "data-path evidence must reach Suspect before the sweeper: \
             {data_path_s:.3}s >= {sweep_only_s:.3}s"
        );
    }
}
