//! Ablation — workflow concurrency through the execution engine: wall-clock
//! throughput of 1 / 4 / 16 / 64 concurrent runs of a two-stage workflow
//! (2 IoT generators -> 1 edge reducer), all submitted before any is
//! awaited. The engine interleaves the runs on its shared worker pool under
//! per-resource admission limits, so throughput should rise until the
//! per-stage compute (a 5 ms clock sleep per instance) saturates the pool.
//!
//! A second series runs the identical code under the simnet `VirtualClock`:
//! the batch completes in wall-clock time that is pure engine overhead (no
//! real sleeping), demonstrating the engine's clock-genericity. Note the
//! per-run *virtual* durations are measured against the single shared
//! monotonic clock, so concurrent runs' advances bleed into each other's
//! reported duration as concurrency grows — per-run virtual timelines are
//! a ROADMAP open item, and this column is reported for visibility, not as
//! a latency model.

use std::collections::HashMap;
use std::sync::Arc;

use edgefaas::bench_harness::{Stats, Table};
use edgefaas::coordinator::functions::FunctionPackage;
use edgefaas::coordinator::RunId;
use edgefaas::simnet::{Clock, RealClock, VirtualClock};
use edgefaas::testbed::{paper_testbed, TestBed};
use edgefaas::util::json::Json;

/// Per-instance modeled compute, seconds.
const STAGE_S: f64 = 0.005;

fn bed_with_chain(clock: Arc<dyn Clock>) -> TestBed {
    let bed = paper_testbed(clock);
    let faas = Arc::clone(&bed.faas);
    let yaml = "\
application: chain
entrypoint: gen
dag:
  - name: gen
    affinity:
      nodetype: iot
      affinitytype: data
    reduce: auto
  - name: sum
    dependencies: gen
    affinity:
      nodetype: edge
      affinitytype: function
    reduce: 1
";
    let mut data = HashMap::new();
    data.insert("gen".to_string(), vec![bed.iot[0], bed.iot[1]]);
    faas.configure_application(yaml, &data).unwrap();
    for stage in ["gen", "sum"] {
        let clock = Arc::clone(faas.clock());
        bed.executor.register(&format!("img/{stage}"), move |_: &[u8]| {
            clock.sleep(STAGE_S); // real sleep or virtual advance
            let mut out = Json::obj();
            out.set("outputs", Json::Arr(vec![]));
            Ok(out.to_string().into_bytes())
        });
    }
    faas.deploy_function("chain", "gen", &FunctionPackage { code: "img/gen".into() }).unwrap();
    faas.deploy_function("chain", "sum", &FunctionPackage { code: "img/sum".into() }).unwrap();
    bed
}

/// Submit `n` runs, then await them all; returns (batch wall seconds, mean
/// per-run reported duration).
fn run_batch(bed: &TestBed, n: usize) -> (f64, f64) {
    let t0 = std::time::Instant::now();
    let ids: Vec<RunId> =
        (0..n).map(|_| bed.faas.submit_workflow("chain", &HashMap::new()).unwrap()).collect();
    let mut durations = Vec::new();
    for id in ids {
        let r = bed.faas.wait_workflow(id, 120.0).unwrap();
        durations.push(r.duration);
    }
    let wall = t0.elapsed().as_secs_f64();
    (wall, durations.iter().sum::<f64>() / n as f64)
}

fn main() {
    let levels = [1usize, 4, 16, 64];

    let mut t = Table::new(
        "Ablation: concurrent workflow runs through the engine (wall clock)",
        &["concurrency", "batch wall", "runs/s", "speedup vs serial"],
    );
    let bed = bed_with_chain(Arc::new(RealClock::new()));
    let (serial_wall, _) = run_batch(&bed, 1); // warm sandboxes
    let mut serial_rate = 1.0 / serial_wall;
    let mut rows = Vec::new();
    for &n in &levels {
        let (wall, _) = run_batch(&bed, n);
        let rate = n as f64 / wall;
        if n == 1 {
            serial_rate = rate;
        }
        rows.push((n, wall, rate));
    }
    for (n, wall, rate) in &rows {
        t.row(&[
            n.to_string(),
            Stats::fmt(*wall),
            format!("{rate:.0}"),
            format!("{:.1}x", rate / serial_rate),
        ]);
    }
    t.print();
    let peak = rows.iter().map(|(_, _, r)| *r).fold(0.0, f64::max);
    assert!(
        peak > serial_rate * 1.5,
        "concurrent submission must beat serial throughput: serial {serial_rate:.0}/s peak {peak:.0}/s"
    );

    let mut tv = Table::new(
        "Same engine under simnet virtual time",
        &["concurrency", "batch wall", "mean virtual duration"],
    );
    let bed = bed_with_chain(Arc::new(VirtualClock::new()));
    let _ = run_batch(&bed, 1); // warm sandboxes (virtual cold starts)
    for &n in &levels {
        let (wall, vdur) = run_batch(&bed, n);
        tv.row(&[n.to_string(), Stats::fmt(wall), format!("{vdur:.3} s")]);
    }
    tv.print();
    println!("\n-> no real sleeping under the virtual clock: the batch's wall time");
    println!("   is pure engine overhead. Per-run virtual durations share one");
    println!("   monotonic clock, so they accumulate with concurrency (per-run");
    println!("   virtual timelines are a ROADMAP open item).");
}
