//! Figure 6 — Communication Latency: time to upload each stage's output to
//! the edge tier vs the cloud tier, over the simnet topology (Fig. 4
//! calibration). Paper anchors: 92 MB video -> edge 8.5 s, -> cloud ~92.7 s.

use edgefaas::bench_harness::Table;
use edgefaas::perfmodel::{analytic, PaperCalib, STAGES};
use edgefaas::simnet::TransferModel;
use edgefaas::testbed::paper_topology;

fn main() {
    let calib = PaperCalib::default();
    let (topo, pis, edges, cloud) = paper_topology();
    let tm = TransferModel::default();
    let mut t = Table::new(
        "Fig. 6: Communication Latency (upload of stage output)",
        &["stage", "to edge (model)", "to cloud (model)", "to edge (simnet)", "to cloud (simnet)"],
    );
    for (i, stage) in STAGES.iter().enumerate() {
        let (e_model, c_model) = analytic::comm_latency(&calib, i);
        let bytes = calib.out_bytes[i];
        let e_sim = tm.time(&topo, pis[0], edges[0], bytes);
        let c_sim = tm.time(&topo, pis[0], cloud, bytes);
        t.row(&[
            stage.name().to_string(),
            format!("{e_model:.2} s"),
            format!("{c_model:.2} s"),
            format!("{e_sim:.2} s"),
            format!("{c_sim:.2} s"),
        ]);
    }
    t.print();
    let (e0, c0) = analytic::comm_latency(&calib, 0);
    println!("\npaper anchors: video->edge 8.5 s (got {e0:.2}), video->cloud ~92.7 s (got {c0:.2})");
    assert!((e0 - 8.5).abs() < 0.2);
    assert!((c0 - 94.8).abs() < 2.0);
    // The simnet path must agree with the analytic model within overheads.
    let c_sim = tm.time(&topo, pis[0], cloud, calib.out_bytes[0]);
    assert!((c_sim - c0).abs() / c0 < 0.02, "simnet vs model: {c_sim} vs {c0}");
}
