//! Ablation/microbenchmark: the coordinator's hot paths. The paper argues
//! EdgeFaaS "is in the critical-path and acts like a router" — so routing
//! and storage-virtualization overheads must be negligible next to network
//! and compute times. Targets (DESIGN.md §7): invoke routing < 5 µs of
//! coordinator overhead, schedule() < 50 µs per DAG.

use std::collections::HashMap;
use std::sync::Arc;

use edgefaas::bench_harness::{measure, Stats, Table};
use edgefaas::coordinator::appconfig::federated_learning_yaml;
use edgefaas::coordinator::functions::FunctionPackage;
use edgefaas::coordinator::storage::ObjectUrl;
use edgefaas::simnet::RealClock;
use edgefaas::testbed::paper_testbed;
use edgefaas::util::json::Json;

fn main() {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let faas = Arc::clone(&bed.faas);
    bed.executor.register("img/noop", |_: &[u8]| Ok(Vec::new()));
    let mut data = HashMap::new();
    data.insert("train".to_string(), bed.iot.clone());
    faas.configure_application(federated_learning_yaml(), &data).unwrap();
    for f in ["train", "firstaggregation", "secondaggregation"] {
        faas.deploy_function("federatedlearning", f, &FunctionPackage { code: "img/noop".into() })
            .unwrap();
    }
    faas.create_bucket("federatedlearning", "bench", Some(bed.cloud)).unwrap();
    let url = faas
        .put_object("federatedlearning", "bench", "obj.bin", &[0u8; 1024])
        .unwrap()
        .to_string();

    let mut t = Table::new(
        "Coordinator hot-path microbenchmarks",
        &["operation", "p50", "p95", "note"],
    );
    let payload = Json::obj();

    let s = measure(50, 500, || {
        faas.invoke("federatedlearning", "secondaggregation", &payload, true).unwrap();
    });
    t.row(&[
        "invoke (1 instance, noop fn)".into(),
        Stats::fmt(s.p50),
        Stats::fmt(s.p95),
        "full path incl sandbox admit".into(),
    ]);

    let s = measure(50, 500, || {
        faas.invoke("federatedlearning", "train", &payload, false).unwrap();
    });
    t.row(&[
        "invoke (8 instances, fan-out)".into(),
        Stats::fmt(s.p50),
        Stats::fmt(s.p95),
        "scoped-thread fan-out".into(),
    ]);

    let s = measure(50, 2000, || {
        faas.candidates_of("federatedlearning", "train").unwrap();
    });
    t.row(&["candidate lookup".into(), Stats::fmt(s.p50), Stats::fmt(s.p95), "mapping read".into()]);

    let s = measure(50, 2000, || {
        let _ = ObjectUrl::parse(&url).unwrap();
    });
    t.row(&["object URL parse".into(), Stats::fmt(s.p50), Stats::fmt(s.p95), "".into()]);

    let s = measure(20, 500, || {
        faas.put_object("federatedlearning", "bench", "obj.bin", &[0u8; 1024]).unwrap();
    });
    t.row(&["put_object 1 KiB".into(), Stats::fmt(s.p50), Stats::fmt(s.p95), "virtual storage".into()]);

    let s = measure(20, 500, || {
        faas.get_object_url(&url).unwrap();
    });
    t.row(&["get_object 1 KiB".into(), Stats::fmt(s.p50), Stats::fmt(s.p95), "".into()]);

    let app = faas.app("federatedlearning").unwrap();
    let train = app.config.function("train").unwrap().clone();
    let req = edgefaas::coordinator::FunctionCreation {
        app: "federatedlearning".into(),
        function: train,
        data_locations: bed.iot.clone(),
        dep_locations: vec![],
    };
    // Measure the full two-phase path: the decision cache would turn these
    // identical repeats into hits (bench §6 of ablation_concurrency covers
    // the cached/snapshot modes).
    faas.set_schedule_cache(false);
    let s = measure(50, 1000, || {
        faas.schedule_function(&req).unwrap();
    });
    t.row(&[
        "schedule_function (phase 1+2)".into(),
        Stats::fmt(s.p50),
        Stats::fmt(s.p95),
        "incl usage scrape + kv backup".into(),
    ]);
    t.print();
}
