//! Figure 8 — End-to-end Latency: running the workflow (from
//! video-processing) entirely on the cloud tier vs entirely on the edge
//! tier. Paper: cloud 96.7 s, edge 12.1 s.
//!
//! Three series: the analytic model, a discrete-event simulation over the
//! Fig. 4 topology (virtual time — exercises `simnet::engine`), and the
//! breakdown into transfer vs compute.

use std::cell::RefCell;
use std::rc::Rc;

use edgefaas::bench_harness::Table;
use edgefaas::perfmodel::{analytic, PaperCalib, STAGES};
use edgefaas::simnet::{SimEngine, TransferModel, Topology};
use edgefaas::testbed::paper_topology;

/// Event-driven pipeline simulation: stage-by-stage transfer + compute for
/// a given partition point; returns the virtual end time.
fn simulate(topo: &Topology, calib: &PaperCalib, partition: usize) -> f64 {
    let (pis, edges, cloud) = ((0..8).collect::<Vec<usize>>(), vec![8usize, 9], 10usize);
    let tm = TransferModel { per_request_overhead: 0.0 };
    let mut eng = SimEngine::new();
    let done = Rc::new(RefCell::new(0.0f64));
    // Recursive stage scheduler via a queue of (stage index, location).
    // The pipeline is linear, so iterate with accumulated delay.
    let mut at = 0.0;
    let mut loc = pis[0];
    for i in 1..STAGES.len() {
        let target = if i <= partition { edges[0] } else { cloud };
        // Ship previous stage's output if we move.
        if loc != target {
            at += tm.time(topo, loc, target, calib.out_bytes[i - 1]);
            loc = target;
        }
        at += calib.compute(STAGES[i], target == cloud);
    }
    {
        let done = Rc::clone(&done);
        eng.schedule(at, move |e| {
            *done.borrow_mut() = e.now();
        });
    }
    eng.run();
    let v = *done.borrow();
    v
}

fn main() {
    let calib = PaperCalib::default();
    let (topo, _, _, _) = paper_topology();
    let mut t = Table::new(
        "Fig. 8: End-to-end Latency (from video-processing)",
        &["deployment", "paper", "analytic model", "event simulation"],
    );
    let cloud_model = analytic::end_to_end(&calib, 0);
    let edge_model = analytic::end_to_end(&calib, 5);
    let cloud_sim = simulate(&topo, &calib, 0);
    let edge_sim = simulate(&topo, &calib, 5);
    t.row(&[
        "cloud tier".into(),
        "96.7 s".into(),
        format!("{cloud_model:.1} s"),
        format!("{cloud_sim:.1} s"),
    ]);
    t.row(&[
        "edge tier".into(),
        "12.1 s".into(),
        format!("{edge_model:.1} s"),
        format!("{edge_sim:.1} s"),
    ]);
    t.print();
    let (ingest_c, _, _, compute_c) = analytic::breakdown(&calib, 0);
    let (ingest_e, compute_e, _, _) = analytic::breakdown(&calib, 5);
    println!("\nbreakdown: cloud = {ingest_c:.1}s transfer + {compute_c:.1}s compute;");
    println!("           edge  = {ingest_e:.1}s transfer + {compute_e:.1}s compute");
    println!("-> the cloud path is dominated by the 92 MB upload; the edge path");
    println!("   pays more compute but saves the WAN (the paper's Fig. 8 argument).");
    assert!((cloud_model - 96.7).abs() < 0.5);
    assert!((edge_model - 12.1).abs() < 0.15);
    assert!((cloud_sim - cloud_model).abs() / cloud_model < 0.03, "sim agrees with model");
    assert!((edge_sim - edge_model).abs() / edge_model < 0.05);
}
