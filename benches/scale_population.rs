//! Scale harness — seeded workload populations over discrete-event time.
//!
//! Drives [`edgefaas::testbed::scale_testbed`] fleets with 1k / 10k / 100k
//! simulated edge devices through the real engine / scheduler / liveness
//! planes under the discrete-event [`SimClock`]: a seeded population
//! (`workloads::population`) turns a `u64` seed into a byte-identical
//! submission schedule, and the replay paces the virtual clock along it
//! with a registered pacer actor so arrivals land at their exact virtual
//! times regardless of host speed.
//!
//! Two parts:
//!
//! 1. **Determinism gate** (always, including smoke): the same seed is
//!    replayed twice on fresh beds in [`RunConfig::determinism`] mode
//!    (deadlines stripped, backpressure raised) — the schedule digest
//!    *and* the outcome/firing digest must be bit-identical, or the
//!    bench panics (nonzero exit, fails CI).
//! 2. **Scale series** (per device count): a measured-mode replay
//!    ([`RunConfig::measured`] — deadlines live, periodic liveness
//!    sweeps) reporting sustained submissions/sec, per-QoS-class p50/p99
//!    virtual end-to-end latency, shed / deadline-miss / saturation
//!    rates, virtual makespan and wall cost. Non-smoke runs 1k / 10k /
//!    100k devices and asserts the 100k replay completes in bounded wall
//!    time with zero hung and zero lost runs.
//!
//! Everything is written to `BENCH_scale.json` (override the path with
//! `BENCH_SCALE_OUT`). `ABLATION_SMOKE=1` runs the determinism gate plus
//! a short 1k-device series only (CI), still producing the artifact.

use std::sync::Arc;

use edgefaas::bench_harness::{Stats, Table};
use edgefaas::simnet::{Clock, SimClock};
use edgefaas::testbed::{scale_testbed, ScaleBed};
use edgefaas::util::json::Json;
use edgefaas::workloads::{
    generate, install_population, run_population, ClassReport, PopulationReport, PopulationSpec,
    RunConfig,
};

/// Every population in this bench derives from this seed.
const SEED: u64 = 0xED6E_FAA5;

struct SeriesCfg {
    label: &'static str,
    devices: usize,
    cells: usize,
    boxes_per_cell: usize,
    duration_s: f64,
}

fn fresh_bed(cells: usize, boxes_per_cell: usize) -> (Arc<SimClock>, ScaleBed) {
    let clock = Arc::new(SimClock::new());
    let bed = scale_testbed(Arc::clone(&clock) as Arc<dyn Clock>, cells, boxes_per_cell);
    (clock, bed)
}

/// One determinism-mode replay on a fresh bed (raised backpressure so no
/// run is shed — shed victims are timing-dependent).
fn determinism_run(devices: usize, cells: usize, duration_s: f64) -> PopulationReport {
    let (clock, bed) = fresh_bed(cells, 4);
    bed.faas.set_backpressure(1_000_000, 1_000_000);
    install_population(&bed.faas, &bed.executor, &bed.cell_boxes).expect("install population");
    let schedule = generate(&PopulationSpec::standard(SEED, devices, cells, duration_s));
    run_population(&bed.faas, &schedule, RunConfig::determinism(Some(clock.actor())))
}

/// One measured-mode replay on a fresh bed.
fn measured_run(s: &SeriesCfg) -> PopulationReport {
    let (clock, bed) = fresh_bed(s.cells, s.boxes_per_cell);
    install_population(&bed.faas, &bed.executor, &bed.cell_boxes).expect("install population");
    let schedule = generate(&PopulationSpec::standard(SEED, s.devices, s.cells, s.duration_s));
    run_population(&bed.faas, &schedule, RunConfig::measured(Some(clock.actor())))
}

fn class_json(c: &ClassReport) -> Json {
    let mut o = Json::obj();
    o.set("submitted", (c.submitted as u64).into())
        .set("completed", (c.completed as u64).into())
        .set("saturated", (c.saturated as u64).into())
        .set("shed", (c.shed as u64).into())
        .set("deadline_missed", (c.deadline_missed as u64).into())
        .set("resource_dead", (c.resource_dead as u64).into())
        .set("failed", (c.failed as u64).into());
    if c.e2e_s.is_empty() {
        o.set("e2e_p50_s", Json::Null).set("e2e_p99_s", Json::Null);
    } else {
        let st = Stats::of(c.e2e_s.clone());
        o.set("e2e_p50_s", st.p50.into()).set("e2e_p99_s", st.p99.into());
    }
    o
}

fn rate(part: usize, whole: usize) -> f64 {
    if whole == 0 {
        0.0
    } else {
        part as f64 / whole as f64
    }
}

fn quantiles(c: &ClassReport) -> (String, String) {
    if c.e2e_s.is_empty() {
        ("-".into(), "-".into())
    } else {
        let st = Stats::of(c.e2e_s.clone());
        (Stats::fmt(st.p50), Stats::fmt(st.p99))
    }
}

fn main() {
    let smoke = std::env::var("ABLATION_SMOKE").map(|v| v == "1").unwrap_or(false);

    // -------------------------------------------------- determinism gate
    let (gate_devices, gate_duration) = if smoke { (200, 20.0) } else { (1000, 30.0) };
    let a = determinism_run(gate_devices, 4, gate_duration);
    let b = determinism_run(gate_devices, 4, gate_duration);
    assert_eq!(
        a.schedule_digest, b.schedule_digest,
        "same-seed populations generated different schedules"
    );
    assert_eq!(
        a.firing_digest, b.firing_digest,
        "same-seed replays produced different outcome/firing digests"
    );
    assert_eq!(a.hung, 0, "determinism replay hung");
    assert_eq!(a.lost, 0, "determinism replay lost run records");
    println!(
        "determinism gate: {} devices, {} submissions, schedule {:016x}, firing {:016x} — \
         identical across two replays",
        gate_devices,
        a.submitted(),
        a.schedule_digest,
        a.firing_digest
    );

    // ------------------------------------------------------ scale series
    let series: Vec<SeriesCfg> = if smoke {
        vec![SeriesCfg {
            label: "1k",
            devices: 1000,
            cells: 8,
            boxes_per_cell: 4,
            duration_s: 20.0,
        }]
    } else {
        vec![
            SeriesCfg { label: "1k", devices: 1000, cells: 8, boxes_per_cell: 4, duration_s: 60.0 },
            SeriesCfg {
                label: "10k",
                devices: 10_000,
                cells: 16,
                boxes_per_cell: 4,
                duration_s: 60.0,
            },
            SeriesCfg {
                label: "100k",
                devices: 100_000,
                cells: 16,
                boxes_per_cell: 8,
                duration_s: 60.0,
            },
        ]
    };

    let mut table = Table::new(
        "Scale harness — seeded populations over discrete-event time",
        &[
            "series", "devices", "submitted", "sub/s", "completed", "shed", "missed", "rt p50",
            "rt p99", "wall",
        ],
    );
    let mut series_json = Vec::new();
    let mut reports = Vec::new();
    for s in &series {
        let r = measured_run(s);
        let submitted = r.submitted();
        let subs_per_s =
            if r.submit_wall_s > 0.0 { submitted as f64 / r.submit_wall_s } else { 0.0 };
        let shed: usize = r.per_class.iter().map(|c| c.shed).sum();
        let missed: usize = r.per_class.iter().map(|c| c.deadline_missed).sum();
        let (rt_p50, rt_p99) = quantiles(&r.per_class[0]);
        table.row(&[
            s.label.to_string(),
            s.devices.to_string(),
            submitted.to_string(),
            format!("{subs_per_s:.0}"),
            r.completed().to_string(),
            format!("{:.1}%", 100.0 * rate(shed, submitted)),
            format!("{:.1}%", 100.0 * rate(missed, submitted)),
            rt_p50,
            rt_p99,
            Stats::fmt(r.wall_s),
        ]);

        let mut o = Json::obj();
        o.set("label", s.label.into())
            .set("devices", (s.devices as u64).into())
            .set("cells", (s.cells as u64).into())
            .set("boxes_per_cell", (s.boxes_per_cell as u64).into())
            .set("duration_virtual_s", s.duration_s.into())
            .set("submitted", (submitted as u64).into())
            .set("completed", (r.completed() as u64).into())
            .set("submissions_per_s", subs_per_s.into())
            .set("shed_rate", rate(shed, submitted).into())
            .set("deadline_miss_rate", rate(missed, submitted).into())
            .set("virtual_makespan_s", r.virtual_makespan_s.into())
            .set("submit_wall_s", r.submit_wall_s.into())
            .set("wall_s", r.wall_s.into())
            .set("lost", (r.lost as u64).into())
            .set("hung", (r.hung as u64).into());
        let mut classes = Json::obj();
        classes
            .set("realtime", class_json(&r.per_class[0]))
            .set("interactive", class_json(&r.per_class[1]))
            .set("batch", class_json(&r.per_class[2]));
        o.set("classes", classes);
        series_json.push(o);
        reports.push(r);
    }
    table.print();

    // --------------------------------------------------------- artifact
    let mut determinism = Json::obj();
    determinism
        .set("seed", (SEED).into())
        .set("devices", (gate_devices as u64).into())
        .set("submitted", (a.submitted() as u64).into())
        .set("schedule_digest", format!("{:016x}", a.schedule_digest).into())
        .set("firing_digest", format!("{:016x}", a.firing_digest).into())
        .set("identical", true.into());
    let mut doc = Json::obj();
    doc.set("bench", "scale_population".into())
        .set("smoke", smoke.into())
        .set("determinism", determinism)
        .set("series", Json::Arr(series_json));
    let out_path =
        std::env::var("BENCH_SCALE_OUT").unwrap_or_else(|_| "BENCH_scale.json".to_string());
    std::fs::write(&out_path, doc.to_string()).expect("write bench json");
    println!("wrote {out_path}");

    // Non-smoke acceptance: the 100k-device replay completes in bounded
    // wall time and never hangs or loses a run record.
    if !smoke {
        let big = reports.last().expect("non-smoke runs the 100k series");
        assert_eq!(big.hung, 0, "100k-device replay hung");
        assert_eq!(big.lost, 0, "100k-device replay lost run records");
        assert!(
            big.wall_s < 900.0,
            "100k-device replay took {:.0} s wall (budget 900 s)",
            big.wall_s
        );
    }
}
