//! Ablation: the design choices DESIGN.md calls out.
//!
//! 1. Data placement (§3.3.2): locality-pinned buckets vs cloud-pinned
//!    buckets — modeled transfer cost for the FL round's model exchange.
//! 2. reduce: auto vs reduce: 1 for the first aggregation — WAN bytes and
//!    aggregation-path latency (the paper's two-level-aggregation claim).

use edgefaas::bench_harness::Table;
use edgefaas::simnet::TransferModel;
use edgefaas::testbed::paper_topology;
use edgefaas::workflows::fedlearn::LENET_PARAMS;

fn main() {
    let (topo, pis, edges, cloud) = paper_topology();
    let tm = TransferModel::default();
    let model_bytes = (LENET_PARAMS * 4 + 22) as u64; // tensor wire format

    // --- ablation 1: where the trained models land --------------------
    // locality: worker writes locally, edge aggregator pulls over LAN.
    let local_pull: f64 =
        (0..8).map(|i| tm.time(&topo, pis[i], edges[i / 4], model_bytes)).sum();
    // cloud-pinned: every worker pushes its model straight to the cloud.
    let cloud_push: f64 = (0..8).map(|i| tm.time(&topo, pis[i], cloud, model_bytes)).sum();
    let mut t = Table::new(
        "Ablation 1: data placement for 8 worker models (247 KB each)",
        &["policy", "total transfer time", "WAN bytes"],
    );
    t.row(&[
        "locality (paper §3.3.2)".into(),
        format!("{local_pull:.2} s"),
        "0 B to cloud at this step".into(),
    ]);
    t.row(&[
        "cloud-pinned".into(),
        format!("{cloud_push:.2} s"),
        format!("{} B", 8 * model_bytes),
    ]);
    t.print();
    assert!(local_pull < cloud_push / 2.0, "locality must win decisively");

    // --- ablation 2: two-level vs one-level aggregation ----------------
    // The WAN uplink is shared: simultaneous uploads serialize on the
    // bottleneck (fluid model). Two-level sends 2 edge aggregates over the
    // WAN; one-level sends all 8 worker models.
    let wan_serialize = |n: u64, from: usize| -> f64 {
        tm.time(&topo, from, cloud, n * model_bytes)
    };
    // two-level: LAN fan-in on each set (4 models share each LAN link),
    // then one aggregate per edge over the WAN.
    let lan_fan_in = [0usize, 1]
        .iter()
        .map(|&set| tm.time(&topo, pis[set * 4], edges[set], 4 * model_bytes))
        .fold(0.0f64, f64::max);
    let two_level_time = lan_fan_in
        + [0usize, 1].iter().map(|&e| wan_serialize(1, edges[e])).fold(0.0f64, f64::max);
    let two_level_wan = 2 * model_bytes;
    // one-level: all 8 models cross the shared WAN bottleneck.
    let one_level_time = [0usize, 1]
        .iter()
        .map(|&set| wan_serialize(4, pis[set * 4]))
        .fold(0.0f64, f64::max);
    let one_level_wan = 8 * model_bytes;
    let mut t = Table::new(
        "Ablation 2: two-level (paper) vs one-level aggregation, per round",
        &["scheme", "critical-path transfer", "WAN bytes"],
    );
    t.row(&[
        "two-level (edge then cloud)".into(),
        format!("{two_level_time:.3} s"),
        format!("{two_level_wan}"),
    ]);
    t.row(&[
        "one-level (all to cloud)".into(),
        format!("{one_level_time:.3} s"),
        format!("{one_level_wan}"),
    ]);
    t.print();
    println!(
        "\ntwo-level aggregation cuts WAN bytes {:.0}% and transfer time {:.0}%",
        (1.0 - two_level_wan as f64 / one_level_wan as f64) * 100.0,
        (1.0 - two_level_time / one_level_time) * 100.0
    );
    assert!(two_level_wan < one_level_wan);
    assert!(two_level_time < one_level_time, "two-level wins once the WAN bottleneck is shared");
}
