//! §5.2 — Federated Learning Workflow deployment: the use-case trace. The
//! coordinator must deploy `train` on each of the 8 Pis where its data
//! lives (privacy=1), `firstaggregation` on the two edge servers (closest
//! per set), and `secondaggregation` once on the cloud (reduce: 1).

use std::collections::HashMap;
use std::sync::Arc;

use edgefaas::bench_harness::{measure, Stats, Table};
use edgefaas::coordinator::appconfig::federated_learning_yaml;
use edgefaas::simnet::RealClock;
use edgefaas::testbed::paper_testbed;

fn main() {
    let bed = paper_testbed(Arc::new(RealClock::new()));
    let faas = Arc::clone(&bed.faas);
    let mut data = HashMap::new();
    data.insert("train".to_string(), bed.iot.clone());
    let plan = faas.configure_application(federated_learning_yaml(), &data).unwrap();

    let mut t = Table::new(
        "Sec. 5.2: FL workflow deployment trace",
        &["function", "paper placement", "EdgeFaaS placement"],
    );
    t.row(&[
        "train".into(),
        "each of the 8 Pis (privacy, data locality)".into(),
        format!("{:?}", plan["train"]),
    ]);
    t.row(&[
        "firstaggregation".into(),
        "the 2 edge servers (closest per set)".into(),
        format!("{:?}", plan["firstaggregation"]),
    ]);
    t.row(&[
        "secondaggregation".into(),
        "the cloud (reduce: 1)".into(),
        format!("{:?}", plan["secondaggregation"]),
    ]);
    t.print();
    assert_eq!(plan["train"], bed.iot);
    assert_eq!(plan["firstaggregation"], bed.edges);
    assert_eq!(plan["secondaggregation"], vec![bed.cloud]);

    // Verify the privacy filter is what pinned `train` to the Pis: the
    // phase-1 candidate set for train must contain no edge/cloud resource.
    let app = faas.app("federatedlearning").unwrap();
    let train = app.config.function("train").unwrap().clone();
    let req = edgefaas::coordinator::FunctionCreation {
        app: "federatedlearning".into(),
        function: train,
        data_locations: bed.iot.clone(),
        dep_locations: vec![],
    };
    let survivors = faas.phase1_filter(&req);
    assert_eq!(survivors.len(), 8, "privacy leaves exactly the data-holding Pis");
    println!("\nphase-1 privacy filter: {} candidates (all IoT) — paper §3.2.3 behaviour", survivors.len());

    let stats = measure(3, 20, || {
        let bed = paper_testbed(Arc::new(RealClock::new()));
        let mut data = HashMap::new();
        data.insert("train".to_string(), bed.iot.clone());
        bed.faas.configure_application(federated_learning_yaml(), &data).unwrap();
    });
    println!("configure_application (FL, 3 functions): p50 {}", Stats::fmt(stats.p50));
}
