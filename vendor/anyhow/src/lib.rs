//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this workspace vendors
//! the small slice of anyhow's surface it actually uses: a dynamic string
//! backed [`Error`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, the
//! [`Result`] alias, and the [`Context`] extension trait for `Option` and
//! `Result`. Semantics match upstream for that slice; error sources are
//! flattened into the message at conversion time instead of being kept as a
//! cause chain.

use std::fmt;

/// A type-erased error: the formatted message of whatever was thrown.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error directly from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like upstream anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion coherent
// and lets `?` lift any std error into an `anyhow::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `Result<T, anyhow::Error>`, with the error type overridable like upstream.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Option` / `Result` values, converting to [`Result`].
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_lifts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "disk on fire");
    }

    #[test]
    fn macros_format_and_wrap() {
        let x = 7;
        let e = anyhow!("x = {x}");
        assert_eq!(e.to_string(), "x = 7");
        let e = anyhow!(io_err());
        assert_eq!(e.to_string(), "disk on fire");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(e.to_string(), "1 and 2");

        fn bails() -> Result<()> {
            bail!("boom {}", 9);
        }
        assert_eq!(bails().unwrap_err().to_string(), "boom 9");

        fn ensures(v: usize) -> Result<usize> {
            ensure!(v < 10, "v too big: {v}");
            ensure!(v != 5);
            Ok(v)
        }
        assert_eq!(ensures(3).unwrap(), 3);
        assert!(ensures(12).unwrap_err().to_string().contains("v too big"));
        assert!(ensures(5).unwrap_err().to_string().contains("v != 5"));
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u8> = None;
        assert_eq!(none.context("empty").unwrap_err().to_string(), "empty");
        assert_eq!(Some(4u8).context("empty").unwrap(), 4);
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("while reading").unwrap_err();
        assert_eq!(e.to_string(), "while reading: disk on fire");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
