//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this workspace vendors
//! the small slice of anyhow's surface it actually uses: a dynamic [`Error`]
//! carrying a pre-rendered message plus (when built from a typed error) the
//! original value for [`Error::downcast_ref`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros, the [`Result`] alias, and the [`Context`] extension
//! trait for `Option` and `Result`. Semantics match upstream for that
//! slice: `?`-lifting a `std::error::Error` and `anyhow!(err)` both keep
//! the typed value downcastable; string contexts flatten into the message
//! without disturbing the payload.

use std::fmt;

/// A type-erased error: a rendered message, plus the originating typed
/// error (when there was one) for downcasting.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error directly from a displayable message (no payload).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Build an error from a typed `std::error::Error`, keeping the value
    /// for [`Error::downcast_ref`].
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prefix the rendered message, keeping the typed payload (the method
    /// form of [`Context::context`], like upstream's `Error::context`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// Borrow the typed payload, if this error was built from one of type
    /// `T`.
    pub fn downcast_ref<T: std::error::Error + 'static>(&self) -> Option<&T> {
        self.source.as_deref().and_then(|s| s.downcast_ref::<T>())
    }

    /// Take back the typed payload, or return `self` unchanged.
    pub fn downcast<T: std::error::Error + Send + Sync + 'static>(
        self,
    ) -> std::result::Result<T, Error> {
        let Error { msg, source } = self;
        match source {
            Some(boxed) => match boxed.downcast::<T>() {
                Ok(t) => Ok(*t),
                Err(boxed) => Err(Error { msg, source: Some(boxed) }),
            },
            None => Err(Error { msg, source: None }),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like upstream anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion coherent
// and lets `?` lift any std error into an `anyhow::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `Result<T, anyhow::Error>`, with the error type overridable like upstream.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Option` / `Result` values, converting to [`Result`].
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}"), source: None })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()), source: None })
    }
}

/// Implementation detail of [`anyhow!`]: upstream's autoref-specialization
/// trick, so `anyhow!(typed_error)` keeps the payload downcastable while
/// `anyhow!(displayable)` still works for plain messages.
#[doc(hidden)]
pub mod private {
    use super::Error;
    use std::fmt::{Debug, Display};

    pub struct Adhoc;
    pub trait AdhocKind: Sized {
        fn anyhow_kind(&self) -> Adhoc {
            Adhoc
        }
    }
    impl<T: ?Sized + Display + Debug + Send + Sync + 'static> AdhocKind for &T {}

    pub struct Trait;
    pub trait TraitKind: Sized {
        fn anyhow_kind(&self) -> Trait {
            Trait
        }
    }
    impl<E: std::error::Error + Send + Sync + 'static> TraitKind for E {}

    impl Adhoc {
        pub fn new<M: Display + Debug + Send + Sync + 'static>(self, message: M) -> Error {
            Error::msg(message)
        }
    }
    impl Trait {
        pub fn new<E: std::error::Error + Send + Sync + 'static>(self, error: E) -> Error {
            Error::new(error)
        }
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
/// A value that implements `std::error::Error` keeps its typed payload
/// (downcastable); anything else becomes a plain message.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {{
        #[allow(unused_imports)]
        use $crate::private::{AdhocKind, TraitKind};
        match $err {
            error => (&error).anyhow_kind().new(error),
        }
    }};
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_lifts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "disk on fire");
    }

    #[test]
    fn macros_format_and_wrap() {
        let x = 7;
        let e = anyhow!("x = {x}");
        assert_eq!(e.to_string(), "x = 7");
        let e = anyhow!(io_err());
        assert_eq!(e.to_string(), "disk on fire");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(e.to_string(), "1 and 2");

        fn bails() -> Result<()> {
            bail!("boom {}", 9);
        }
        assert_eq!(bails().unwrap_err().to_string(), "boom 9");

        fn ensures(v: usize) -> Result<usize> {
            ensure!(v < 10, "v too big: {v}");
            ensure!(v != 5);
            Ok(v)
        }
        assert_eq!(ensures(3).unwrap(), 3);
        assert!(ensures(12).unwrap_err().to_string().contains("v too big"));
        assert!(ensures(5).unwrap_err().to_string().contains("v != 5"));
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u8> = None;
        assert_eq!(none.context("empty").unwrap_err().to_string(), "empty");
        assert_eq!(Some(4u8).context("empty").unwrap(), 4);
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("while reading").unwrap_err();
        assert_eq!(e.to_string(), "while reading: disk on fire");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }

    #[test]
    fn typed_payload_survives_lifting_and_downcasts() {
        // `?`-lifted.
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.downcast_ref::<std::io::Error>().unwrap().kind(), std::io::ErrorKind::Other);
        // anyhow!(typed) and bail!(typed).
        let e = anyhow!(io_err());
        assert!(e.downcast_ref::<std::io::Error>().is_some());
        fn bails() -> Result<()> {
            bail!(io_err());
        }
        assert!(bails().unwrap_err().downcast_ref::<std::io::Error>().is_some());
        // anyhow!(plain displayable) has no payload.
        let e = anyhow!("just text".to_string());
        assert!(e.downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn error_context_keeps_payload_and_prefixes_message() {
        let e = Error::new(io_err()).context("while flushing");
        assert_eq!(e.to_string(), "while flushing: disk on fire");
        assert!(e.downcast_ref::<std::io::Error>().is_some());
    }

    #[test]
    fn downcast_by_value_roundtrips() {
        let e = Error::new(io_err());
        let io = e.downcast::<std::io::Error>().unwrap();
        assert_eq!(io.to_string(), "disk on fire");
        let e = Error::msg("plain");
        let e = e.downcast::<std::io::Error>().unwrap_err();
        assert_eq!(e.to_string(), "plain");
    }
}
