//! Offline stand-in for the `log` facade crate.
//!
//! Vendored because the build environment has no crates.io access. Provides
//! the subset this workspace uses: the level/filter enums (with the
//! cross-type ordering the real crate has), the [`Log`] trait with
//! [`Record`]/[`Metadata`], the global logger registration, and the five
//! level macros.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a log record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let name = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.pad(name)
    }
}

/// Maximum-verbosity filter installed with [`set_max_level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record (just the level in this shim).
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log record, borrowed for the duration of the `Log::log` call.
pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
    target: &'a str,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logger implementation, installed once with [`set_logger`].
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

/// Returned when [`set_logger`] is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

/// Install the global logger. Fails if one is already installed.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum verbosity.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::SeqCst);
}

/// The current global maximum verbosity.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro back-end: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level }, args, target };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

/// Log at an explicit level.
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if (lvl as usize) <= ($crate::max_level() as usize) {
            $crate::__private_log(lvl, ::std::module_path!(), ::std::format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Error, $($arg)+));
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Warn, $($arg)+));
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Info, $($arg)+));
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Debug, $($arg)+));
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Trace, $($arg)+));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    struct Capture {
        lines: Mutex<Vec<String>>,
    }

    impl Log for Capture {
        fn enabled(&self, _: &Metadata) -> bool {
            true
        }
        fn log(&self, record: &Record) {
            self.lines.lock().unwrap().push(format!("{:5} {}", record.level(), record.args()));
        }
        fn flush(&self) {}
    }

    static CAPTURE: OnceLock<Capture> = OnceLock::new();

    #[test]
    fn levels_compare_with_filters() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));
        assert!(!(Level::Error <= LevelFilter::Off));
    }

    #[test]
    fn records_reach_the_installed_logger() {
        let cap = CAPTURE.get_or_init(|| Capture { lines: Mutex::new(Vec::new()) });
        let _ = set_logger(cap);
        set_max_level(LevelFilter::Info);
        info!("hello {}", 42);
        debug!("filtered out");
        let lines = cap.lines.lock().unwrap();
        assert!(lines.iter().any(|l| l.contains("hello 42")), "{lines:?}");
        assert!(!lines.iter().any(|l| l.contains("filtered out")));
    }
}
